"""Serving example: batched requests through prefill + KV-cache decode.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-27b

Uses the smoke variant of the selected arch (full configs need a pod).
Shows the RequestBatcher packing variable-length prompts into one compiled
shape and greedy decode over the rolling/sliding-window caches.
"""

import argparse
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer as T
from repro.serve.decode import RequestBatcher, generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b",
                    choices=list(registry.ARCH_IDS))
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = registry.get_smoke(args.arch)
    print(f"serving {cfg.name} ({cfg.num_params() / 1e6:.1f}M params, "
          f"pattern={cfg.pattern})")
    params = T.init(jax.random.key(0), cfg)

    batcher = RequestBatcher(batch_size=4, seq_len=16)
    requests = [
        [3, 1, 4, 1, 5, 9, 2, 6],
        [2, 7, 1, 8],
        [1, 1, 2, 3, 5, 8, 13],
    ]
    prompts, lens, n = batcher.pack(requests)

    vision = None
    if cfg.vision_tokens:
        vision = jax.random.normal(
            jax.random.key(1), (4, cfg.vision_tokens, cfg.cross_kv_dim))

    toks = generate(params, prompts, cfg, max_new_tokens=args.new_tokens,
                    vision=vision)
    for i, out in enumerate(batcher.unpack(toks, n)):
        print(f"request {i}: prompt={requests[i]} -> generated={out}")


if __name__ == "__main__":
    main()
