"""End-to-end driver: train a ~100M-param qwen3-family LM for a few hundred
steps with the paper's distributed recipe (2D-torus grad sync + LARS +
label smoothing + batch-size control).

    PYTHONPATH=src python examples/train_lm_100m.py [--steps 200]

On the 8-host-device CPU mesh this takes a while; --steps 40 for a quick
pass. Checkpoints land in /tmp/repro_lm100m.
"""

import argparse
import dataclasses
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticTokens
from repro.models import transformer as T
from repro.train.state import TrainState
from repro.train.trainer import Trainer, TrainerConfig


def lm_100m() -> T.ArchConfig:
    """qwen3 family scaled to ~100M params (8L, d=512, vocab 32k)."""
    base = registry.get("qwen3-1.7b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=1536, vocab=32_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    cfg = lm_100m()
    n_params = cfg.num_params()
    print(f"arch {cfg.name}: {n_params / 1e6:.1f}M params")

    data = SyntheticTokens(vocab=cfg.vocab)

    def loss_fn(params, batch, dp_axes):
        tokens, labels = batch
        logits, aux = T.forward(params, tokens, cfg)
        return losses.label_smoothing_xent(logits, labels, 0.1), aux

    sched = BatchSchedule((BatchStage(0, 0.5, 1), BatchStage(0.5, 2.0, 2)))
    plan = build_plan(sched, dataset_size=8 * 2048, n_workers=8,
                      max_steps=args.steps)
    trainer = Trainer(
        mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
        cfg=TrainerConfig(schedule="B",
                          grad_sync=GradSyncConfig(strategy="torus2d",
                                                   fuse=False,
                                                   comm_dtype=jnp.bfloat16)),
        plan=plan, data_fn=lambda i, gb: data.batch(i, gb, args.seq),
        checkpoint_dir="/tmp/repro_lm100m")

    state = TrainState.create(T.init(jax.random.key(0), cfg))
    state, history = trainer.run(state)
    print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"over {int(state.step)} steps")


if __name__ == "__main__":
    main()
