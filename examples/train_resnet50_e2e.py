"""End-to-end driver: the paper's exact model (ResNet-50, 25.6M params)
trained for a few hundred steps with the complete recipe -- 2D-torus grad
sync, LARS, label smoothing, batch-size control, SyncBN, bf16 compute.

    PYTHONPATH=src python examples/train_resnet50_e2e.py [--steps 300]
                                                         [--image-size 64]

Reduced image resolution keeps CPU wall-time sane; every component is the
production path. History is printed and written to
experiments/e2e_resnet50_history.json.
"""

import argparse
import json
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.data import augment
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticImageNet
from repro.models import resnet
from repro.train.state import TrainState
from repro.train.trainer import Trainer, TrainerConfig


@jax.jit
def _augment_batch(key, images):
    return augment.augment(key, images, out_hw=images.shape[1:3])


def _augmented(data, i, gb, image_size):
    """Paper §3.2 augmentation pipeline applied on-device."""
    images, labels = data.batch(i, gb)
    return _augment_batch(jax.random.key(i), images), labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--image-size", type=int, default=64)
    ap.add_argument("--classes", type=int, default=64)
    args = ap.parse_args()

    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    cfg = resnet.ResNetConfig.resnet50(num_classes=args.classes,
                                       image_size=args.image_size)
    data = SyntheticImageNet(num_classes=args.classes,
                             image_size=args.image_size, noise=1.0)

    def loss_fn(params, batch, dp_axes):
        images, labels = batch
        logits = resnet.apply(params, images, cfg, dp_axes=dp_axes)
        return (losses.label_smoothing_xent(logits, labels, 0.1),
                jnp.zeros((), jnp.float32))

    # Exp.1-style batch-size control: 2/worker -> 4/worker at 1/3 of run
    sched = BatchSchedule((BatchStage(0, 1.0, 2), BatchStage(1.0, 4.0, 4)))
    plan = build_plan(sched, dataset_size=4096, n_workers=8,
                      max_steps=args.steps)
    trainer = Trainer(
        mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
        cfg=TrainerConfig(schedule="B", label_smoothing=0.1,
                          grad_sync=GradSyncConfig(strategy="torus2d",
                                                   comm_dtype=jnp.bfloat16),
                          log_every=10),
        plan=plan, data_fn=lambda i, gb: _augmented(data, i, gb,
                                                    args.image_size))

    params = resnet.init(jax.random.key(0), cfg)
    print(f"ResNet-50: {resnet.num_params(params) / 1e6:.1f}M params, "
          f"{args.image_size}px, plan {plan.total_steps} steps")
    state, history = trainer.run(TrainState.create(params))

    os.makedirs("experiments", exist_ok=True)
    with open("experiments/e2e_resnet50_history.json", "w") as f:
        json.dump(history, f, indent=1)
    print(f"loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
          f"({int(state.step)} steps)")


if __name__ == "__main__":
    main()
