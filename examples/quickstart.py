"""Quickstart: the paper's full recipe on a tiny ResNet in ~a minute on CPU.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates: 2D-torus gradient sync, LARS, label smoothing, batch-size
control, SyncBN, mixed precision -- the complete Sony recipe at toy scale.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticImageNet
from repro.models import resnet
from repro.train.state import TrainState
from repro.train.trainer import Trainer, TrainerConfig


def main():
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))   # 2x4 logical 2D torus
    cfg = resnet.ResNetConfig.tiny(num_classes=8)
    data = SyntheticImageNet(num_classes=8, image_size=32, noise=0.4)

    def loss_fn(params, batch, dp_axes):
        images, labels = batch
        logits = resnet.apply(params, images, cfg, dp_axes=dp_axes)
        return (losses.label_smoothing_xent(logits, labels, 0.1),
                jnp.zeros((), jnp.float32))

    # batch-size control: 2/worker then 4/worker (paper §2.1, Table 3)
    sched = BatchSchedule((BatchStage(0, 0.1, 2), BatchStage(0.1, 0.25, 4)))
    plan = build_plan(sched, dataset_size=4096, n_workers=8)
    print(f"plan: {plan.total_steps} steps over {len(plan.stages)} stages")

    trainer = Trainer(
        mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
        cfg=TrainerConfig(
            schedule="B", label_smoothing=0.1,
            grad_sync=GradSyncConfig(strategy="torus2d",
                                     comm_dtype=jnp.bfloat16)),
        plan=plan, data_fn=lambda i, gb: data.batch(i, gb))

    state = TrainState.create(resnet.init(jax.random.key(0), cfg))
    state, history = trainer.run(state)
    print(f"final loss {history[-1]['loss']:.4f} after {int(state.step)} steps")


if __name__ == "__main__":
    main()
