"""Paper Table 1 / Table 6 analogue: time-to-train and scaling efficiency.

Wall-clock ImageNet time cannot be measured here; we reproduce the paper's
tables with the calibrated analytic model (single-GPU throughput from the
paper's own Table 6 anchor + the alpha-beta communication model of
core.collectives):

  table6: images/s and scaling efficiency at 4..4096 GPUs with 2D-torus
          (compare: paper measured 84.75% @1024, 73.44% @4096)
  table1: end-to-end 90-epoch time at Exp-2 settings (3456 GPUs, 54K batch)
          (paper: 122 s for the Exp-2 recipe)

Also a real measured number: local train-step wall time of the tiny ResNet
(per-image us on this CPU) to anchor that the step function itself is real.
"""

from __future__ import annotations

import time

import jax

from repro.core import collectives
from repro.core.topology import paper_table4_grid

IMAGENET = 1_281_167
EPOCHS = 90
PER_GPU = 2565 / 4            # img/s/GPU measured by the paper at 1 node
GRAD_BYTES = 51e6             # fp16 ResNet-50 gradient
LINK_BW = 25e9                # IB EDR x2 per the paper's hardware
LATENCY = 5e-6


def _step_time(n_gpus: int, per_worker: int = 32) -> float:
    y, x = paper_table4_grid(n_gpus)
    comm = collectives.comm_cost_model("torus2d", GRAD_BYTES, x, y,
                                       LINK_BW, LATENCY)["seconds"]
    return per_worker / PER_GPU + comm


def run() -> list[dict]:
    rows = []
    paper_tbl6 = {4: 2565, 1024: 556522, 2048: 1091357,
                  3456: 1641853, 4096: 1929054}
    base = 32 / _step_time(4)              # img/s/GPU at 4 GPUs (reference)
    for n in (4, 1024, 2048, 3456, 4096):
        ips = n * 32 / _step_time(n)
        eff = (ips / n) / base * 100
        rows.append({
            "name": f"table6_throughput_n{n}",
            "us_per_call": round(_step_time(n) * 1e6, 1),
            "derived": f"img/s={ips:.0f},eff={eff:.1f}%,paper={paper_tbl6[n]}",
        })

    # Table 1: Exp-2 (3456 GPUs, 54K batch: 16/worker) 90-epoch time
    t_step = _step_time(3456, per_worker=16)
    steps = EPOCHS * IMAGENET / (16 * 3456)
    total = steps * t_step
    rows.append({"name": "table1_exp2_time",
                 "us_per_call": round(t_step * 1e6, 1),
                 "derived": f"predicted={total:.0f}s,paper=122s"})

    # measured: one real local ResNet-tiny step on this host
    from repro.data.synthetic import SyntheticImageNet
    from repro.models import resnet
    cfg = resnet.ResNetConfig.tiny()
    params = resnet.init(jax.random.key(0), cfg)
    data = SyntheticImageNet(num_classes=10, image_size=32)
    imgs, labels = data.batch(0, 8)

    @jax.jit
    def fwd(p, x):
        return resnet.apply(p, x, cfg).sum()

    fwd(params, imgs).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(5):
        fwd(params, imgs).block_until_ready()
    us = (time.perf_counter() - t0) / 5 * 1e6
    rows.append({"name": "measured_resnet_tiny_fwd",
                 "us_per_call": round(us, 1), "derived": "8img,cpu"})
    return rows
