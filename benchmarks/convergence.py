"""Paper Table 5 analogue: large-batch stabilization ablation, scaled down.

The paper's claim: label smoothing enables 54K initial batch (Exp. 2) and
batch-size control enables up to 119K max batch (Exp. 4) with no
significant accuracy loss vs the 32K reference. At container scale we
reproduce the *relative* effect on a tiny ResNet + synthetic ImageNet with
a deliberately large batch-to-dataset ratio (the large-mini-batch regime):

  reference   : plain CE, flat batch
  + LS        : label smoothing 0.1, flat batch          (Exp. 2 analogue)
  + LS + BSC  : LS + batch-size control 2->4/worker      (Exp. 3/4 analogue)

Reported: final train loss + held-out accuracy per recipe. The paper-level
assertion validated here: LS and LS+BSC both reach >= reference accuracy.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticImageNet
from repro.models import resnet
from repro.train.state import TrainState
from repro.train.trainer import GuardConfig, Trainer, TrainerConfig

N_CLASSES = 8
STEPS = 60
SEEDS = (0, 1)
DATASET = 640          # small dataset -> fast epoch advance -> aggressive LR
                       # (the large-mini-batch instability regime, scaled)


def _loss_fn(cfg, smoothing):
    def loss_fn(params, batch, dp_axes):
        images, labels = batch
        logits = resnet.apply(params, images, cfg, dp_axes=dp_axes)
        return (losses.label_smoothing_xent(logits, labels, smoothing),
                jnp.zeros((), jnp.float32))
    return loss_fn


def _eval_acc(params, cfg, data, steps=4, bs=32):
    accs = []
    for i in range(1000, 1000 + steps):
        images, labels = data.batch(i, bs)
        # eval with the batch's own stats (BN w/o moving average: a
        # calibration batch provides statistics)
        logits, _ = resnet.apply(params, images, cfg, collect_stats=True)
        accs.append(float(losses.top1_accuracy(logits, labels)))
    return float(np.mean(accs))


def run() -> list[dict]:
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    cfg = resnet.ResNetConfig.tiny(num_classes=N_CLASSES)
    data = SyntheticImageNet(num_classes=N_CLASSES, image_size=32, noise=1.0)

    flat = BatchSchedule((BatchStage(0, 3.0, 4),))
    bsc = BatchSchedule((BatchStage(0, 1.0, 2), BatchStage(1.0, 3.0, 4)))

    # fp16-style guard: the paper's precision regime (loss scaled by 2**15).
    # The convergence gate below asserts the scale *settles* -- at most 1%
    # of steps skipped -- instead of sawtoothing overflow/backoff.
    fp16_guard = GuardConfig(init_scale=2.0 ** 15, growth_interval=25)

    recipes = {
        "reference": (0.0, flat, GuardConfig()),
        "label_smooth": (0.1, flat, GuardConfig()),
        "ls_batch_ctrl": (0.1, bsc, GuardConfig()),
        "fp16_guard": (0.1, flat, fp16_guard),
    }
    rows = []
    for name, (smooth, sched, guard) in recipes.items():
        plan = build_plan(sched, dataset_size=DATASET, n_workers=8,
                          max_steps=STEPS)
        tcfg = TrainerConfig(
            schedule="B", label_smoothing=smooth,
            grad_sync=GradSyncConfig(strategy="torus2d",
                                     comm_dtype=jnp.float32),
            guard=guard, log_every=1000)
        accs, final_losses = [], []
        t0 = time.perf_counter()
        steps_done = skipped = 0
        final_scale = guard.init_scale
        for seed in SEEDS:
            trainer = Trainer(mesh=mesh, dp_axes=("dy", "dx"),
                              loss_fn=_loss_fn(cfg, smooth), cfg=tcfg,
                              plan=plan,
                              data_fn=lambda i, gb: data.batch(i, gb))
            state = TrainState.create(
                resnet.init(jax.random.key(seed), cfg),
                loss_scale=guard.init_scale)
            state, hist = trainer.run(state, log=lambda *a: None)
            steps_done += int(state.step)
            skipped += sum(int(h.get("skipped", 0)) for h in hist
                           if "event" not in h)
            final_scale = float(state.loss_scale)
            final_losses.append(hist[-1]["loss"])
            accs.append(_eval_acc(state.params, cfg, data))
        dt = (time.perf_counter() - t0) / max(steps_done, 1) * 1e6
        skip_rate = skipped / max(steps_done, 1)
        if name == "fp16_guard":
            assert skip_rate <= 0.01, (
                f"fp16 loss scale did not settle: {skip_rate:.1%} of steps "
                f"skipped (> 1%)")
            assert final_scale >= guard.init_scale, (
                f"fp16 loss scale collapsed to {final_scale:g}")
        derived = (f"loss={np.mean(final_losses):.3f},"
                   f"acc={np.mean(accs):.3f}")
        if name == "fp16_guard":
            derived += f",skip_rate={skip_rate:.3f},scale={final_scale:g}"
        rows.append({"name": f"table5_{name}",
                     "us_per_call": round(dt, 0),
                     "derived": derived})
    return rows
