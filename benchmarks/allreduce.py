"""Paper Table 2 / Table 6 analogue: all-reduce scheme comparison.

Two parts:

1. **Microbenchmark** (8 host devices): wall time of one 100MB-gradient
   all-reduce per strategy x lowering. CPU wall-times are not TPU times,
   but the *relative* ordering of strategies on the same fabric is the
   paper's claim and is fabric-independent at fixed byte volumes.

2. **Analytic alpha-beta model** at the paper's scales (Table 4 grids,
   V100 + 2x IB-EDR: ~25 GB/s/link, 5 us latency) and at the TPU target
   (50 GB/s ICI): steps, wire bytes, estimated seconds, and the derived
   GPU-scaling-efficiency column the paper reports (Table 6).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives
from repro.core.topology import TorusGrid, paper_table4_grid

RESNET50_GRAD_BYTES = 102e6          # ~25.5M params, fp32; fp16 = half
IMG_PER_SEC_1GPU = 2565 / 4          # paper Table 6: 4 GPUs = 2565 img/s


def microbench(nbytes: int = 8 << 20, iters: int = 5) -> list[dict]:
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))
    n = nbytes // 4
    n -= n % 64
    from jax.sharding import PartitionSpec as P
    rows = []
    for strategy in ("psum", "ring", "hierarchical", "torus2d"):
        for lowering in ("xla", "ring"):
            @functools.partial(jax.shard_map, mesh=mesh, in_specs=P(("dy", "dx")),
                               out_specs=P(("dy", "dx")), check_vma=False)
            def f(x):
                return collectives.all_reduce(x[0], grid, strategy, lowering)[None]

            x = jnp.zeros((8, n // 8), jnp.float32)
            fn = jax.jit(f)
            fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn(x).block_until_ready()
            us = (time.perf_counter() - t0) / iters * 1e6
            rows.append({"name": f"allreduce_{strategy}_{lowering}",
                         "us_per_call": round(us, 1),
                         "derived": f"{nbytes / 2**20:.0f}MiB,8dev"})
    return rows


def analytic_table() -> list[dict]:
    """Cost model at paper scales + TPU target; derived = predicted scaling
    efficiency vs the paper's measured one where available."""
    rows = []
    paper_meas = {1024: 84.75, 2048: 83.10, 3456: 74.08, 4096: 73.44}
    for n in (1024, 2048, 3456, 4096):
        y, x = paper_table4_grid(n)
        per_gpu_img = IMG_PER_SEC_1GPU
        compute_t = 32 / per_gpu_img            # 32 img per worker per step
        for strategy in ("ring", "hierarchical", "torus2d"):
            c = collectives.comm_cost_model(
                strategy, RESNET50_GRAD_BYTES / 2,  # fp16 exchange
                x, y, link_bw=25e9, latency=5e-6)
            eff = compute_t / (compute_t + c["seconds"]) * 100
            meas = paper_meas.get(n) if strategy == "torus2d" else None
            rows.append({
                "name": f"model_{strategy}_n{n}",
                "us_per_call": round(c["seconds"] * 1e6, 1),
                "derived": (f"eff={eff:.1f}%"
                            + (f",paper={meas}%" if meas else "")),
            })
    # TPU target mesh: 256-chip pod as 16x16 torus, bf16 exchange
    for strategy in ("ring", "hierarchical", "torus2d"):
        c = collectives.comm_cost_model(
            strategy, RESNET50_GRAD_BYTES / 2, 16, 16,
            link_bw=50e9, latency=1e-6)
        rows.append({"name": f"tpu_model_{strategy}_16x16",
                     "us_per_call": round(c["seconds"] * 1e6, 1),
                     "derived": f"steps={c['steps']}"})
    return rows


def run() -> list[dict]:
    return microbench() + analytic_table()
