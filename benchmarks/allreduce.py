"""Paper Table 2 / Table 6 analogue: all-reduce scheme comparison.

Three parts:

1. **Microbenchmark** (8 host devices): wall time of one 100MB-gradient
   all-reduce per strategy x lowering. CPU wall-times are not TPU times,
   but the *relative* ordering of strategies on the same fabric is the
   paper's claim and is fabric-independent at fixed byte volumes.

2. **Analytic alpha-beta model** at the paper's scales (Table 4 grids,
   V100 + 2x IB-EDR: ~25 GB/s/link, 5 us latency) and at the TPU target
   (50 GB/s ICI): steps, wire bytes, estimated seconds, and the derived
   GPU-scaling-efficiency column the paper reports (Table 6).

3. **Bucket-size sweep** (``--sweep-bucket-bytes``): for each candidate
   ``bucket_bytes`` of the bucketed gradient-sync pipeline, the measured
   wall time of syncing a ResNet-50-like gradient pytree on 8 host
   devices, the number of independent exchanges the compiled HLO shows
   (the overlap opportunity), and the ``bucketed_comm_cost_model``
   prediction at the TPU target (exposed comm after overlapping a ~40 ms
   backward pass). Small buckets pay k x step latency; one bucket cannot
   overlap at all -- the sweep exposes the tradeoff the paper's bucket
   fusion tunes.
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.core import autotune, collectives
from repro.core.grad_sync import GradSyncConfig, bucket_layout, sync_tree
from repro.core.topology import TorusGrid, paper_table4_grid
from repro.launch import hlo_stats

RESNET50_GRAD_BYTES = 102e6          # ~25.5M params, fp32; fp16 = half
IMG_PER_SEC_1GPU = 2565 / 4          # paper Table 6: 4 GPUs = 2565 img/s

# TPU target for the sweep's cost-model column: 16x16 torus, 50 GB/s ICI,
# ~40 ms ResNet-50 backward at the paper's per-worker batch
TPU_X, TPU_Y = 16, 16
TPU_LINK_BW, TPU_LATENCY = 50e9, 1e-6
BACKWARD_SECONDS = 0.040

DEFAULT_SWEEP = [0, 1 << 20, 4 << 20, 16 << 20, 64 << 20]


def microbench(nbytes: int = 8 << 20, iters: int = 5) -> list[dict]:
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))
    n = nbytes // 4
    n -= n % 64
    rows = []
    for strategy in ("psum", "ring", "hierarchical", "torus2d"):
        for lowering in ("xla", "ring"):
            @functools.partial(shard_map, mesh=mesh, in_specs=P(("dy", "dx")),
                               out_specs=P(("dy", "dx")), check_vma=False)
            def f(x):
                return collectives.all_reduce(x[0], grid, strategy, lowering)[None]

            x = jnp.zeros((8, n // 8), jnp.float32)
            fn = jax.jit(f)
            fn(x).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(iters):
                fn(x).block_until_ready()
            us = (time.perf_counter() - t0) / iters * 1e6
            rows.append({"name": f"allreduce_{strategy}_{lowering}",
                         "us_per_call": round(us, 1),
                         "derived": f"{nbytes / 2**20:.0f}MiB,8dev"})
    return rows


def analytic_table() -> list[dict]:
    """Cost model at paper scales + TPU target; derived = predicted scaling
    efficiency vs the paper's measured one where available."""
    rows = []
    paper_meas = {1024: 84.75, 2048: 83.10, 3456: 74.08, 4096: 73.44}
    for n in (1024, 2048, 3456, 4096):
        y, x = paper_table4_grid(n)
        per_gpu_img = IMG_PER_SEC_1GPU
        compute_t = 32 / per_gpu_img            # 32 img per worker per step
        for strategy in ("ring", "hierarchical", "torus2d"):
            c = collectives.comm_cost_model(
                strategy, RESNET50_GRAD_BYTES / 2,  # fp16 exchange
                x, y, link_bw=25e9, latency=5e-6)
            eff = compute_t / (compute_t + c["seconds"]) * 100
            meas = paper_meas.get(n) if strategy == "torus2d" else None
            rows.append({
                "name": f"model_{strategy}_n{n}",
                "us_per_call": round(c["seconds"] * 1e6, 1),
                "derived": (f"eff={eff:.1f}%"
                            + (f",paper={meas}%" if meas else "")),
            })
    # TPU target mesh: 256-chip pod as 16x16 torus, bf16 exchange
    for strategy in ("ring", "hierarchical", "torus2d"):
        c = collectives.comm_cost_model(
            strategy, RESNET50_GRAD_BYTES / 2, 16, 16,
            link_bw=50e9, latency=1e-6)
        rows.append({"name": f"tpu_model_{strategy}_16x16",
                     "us_per_call": round(c["seconds"] * 1e6, 1),
                     "derived": f"steps={c['steps']}"})
    return rows


# ---------------------------------------------------------------------------
# bucket-size sweep
# ---------------------------------------------------------------------------

def _resnet_like_tree(total_floats: int = 1 << 21) -> dict:
    """A gradient pytree with ResNet-ish layer-size spread: a few big conv
    kernels, many medium ones, a tail of tiny BN scales/biases."""
    rng = np.random.RandomState(0)
    tree: dict = {}
    big = total_floats // 4
    tree["fc"] = {"kernel": jnp.asarray(rng.randn(big // 64, 64), jnp.float32)}
    remaining = total_floats - big
    i = 0
    while remaining > 0:
        n = min(remaining, max(1024, remaining // 6))
        tree[f"conv{i}"] = {
            "kernel": jnp.asarray(rng.randn(max(1, n // 16), 16), jnp.float32),
            "bn_scale": jnp.asarray(rng.randn(32), jnp.float32),
        }
        remaining -= n
        i += 1
    return tree


def bucket_sweep(bucket_bytes_list=DEFAULT_SWEEP, strategy: str = "torus2d",
                 iters: int = 5) -> list[dict]:
    """Measured wall time + HLO exchange count + TPU-target cost model for
    each bucket size. ``bucket_bytes=0`` is the single-fused-buffer baseline."""
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))
    tree = _resnet_like_tree()
    rows = []
    for bb in bucket_bytes_list:
        cfg = GradSyncConfig(strategy=strategy, fuse=True,
                             comm_dtype=jnp.float32, bucket_bytes=bb)
        n_buckets = len(bucket_layout(tree, cfg))

        @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        def f(t):
            return sync_tree(t, grid, cfg)

        fn = jax.jit(f)
        audit = hlo_stats.bucket_audit(
            fn.lower(tree).compile().as_text(), min_bytes=1024)
        fn(tree)["fc"]["kernel"].block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(tree)["fc"]["kernel"].block_until_ready()
        us = (time.perf_counter() - t0) / iters * 1e6

        model = collectives.bucketed_comm_cost_model(
            strategy, RESNET50_GRAD_BYTES / 2, bb, TPU_X, TPU_Y,
            TPU_LINK_BW, TPU_LATENCY, backward_seconds=BACKWARD_SECONDS)
        rows.append({
            "name": f"bucket_sweep_{strategy}_bb{bb}",
            "us_per_call": round(us, 1),
            "derived": (f"buckets={n_buckets},hlo_exchanges="
                        f"{audit['num_exchanges']},tpu_exposed_us="
                        f"{model['exposed_seconds'] * 1e6:.0f},tpu_win_us="
                        f"{model['overlap_win_seconds'] * 1e6:.0f}"),
        })

    # the autotuner's pick at the TPU target, over the union of the swept
    # sizes and its own grid -- the row the sweep is ultimately *for*
    hw = autotune.HardwareModel(link_bw=TPU_LINK_BW, latency_s=TPU_LATENCY,
                                backward_seconds=BACKWARD_SECONDS,
                                name="tpu-16x16")
    total = RESNET50_GRAD_BYTES / 2
    knee = autotune.analytic_knee_bytes(strategy, TPU_X, TPU_Y, hw)
    union = sorted(set(int(b) for b in bucket_bytes_list)
                   | set(autotune.candidate_bucket_bytes(knee, int(total))))
    rec = autotune.recommend_bucket_bytes(strategy, TPU_X, TPU_Y, hw,
                                          total_bytes=total,
                                          candidates=union)
    bracket = autotune.sweep_bracket(
        [{"bucket_bytes": r["bucket_bytes"],
          "exposed_seconds": r["exposed_seconds"]}
         for r in rec["candidates"]])
    rows.append({
        "name": f"bucket_autotune_{strategy}",
        "us_per_call": round(rec["exposed_seconds"] * 1e6, 1),
        "derived": (f"pick={rec['bucket_bytes']},buckets="
                    f"{rec['num_buckets']},knee={knee},within_bracket="
                    f"{autotune.pick_within_bracket(rec['bucket_bytes'], bracket)}"),
    })
    return rows


def run() -> list[dict]:
    return microbench() + analytic_table() + bucket_sweep()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sweep-bucket-bytes", nargs="?", const=",".join(
        str(b) for b in DEFAULT_SWEEP), default=None, metavar="BYTES,...",
        help="run only the bucket-size sweep (optionally a comma-separated "
             "list of bucket sizes; 0 = fused baseline)")
    ap.add_argument("--strategy", default="torus2d",
                    choices=sorted(collectives.STRATEGIES))
    args = ap.parse_args()

    if args.sweep_bucket_bytes is not None:
        sizes = [int(s) for s in args.sweep_bucket_bytes.split(",")]
        rows = bucket_sweep(sizes, strategy=args.strategy)
    else:
        rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
