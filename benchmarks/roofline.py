"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

For every experiments/dryrun/*.json:

    compute term    = HLO_FLOPs_per_chip / 197e12           (bf16 MXU peak)
    memory term     = HLO_bytes_per_chip / 819e9             (HBM bw)
    collective term = collective_bytes_per_chip / 50e9       (ICI per link)

``cost_analysis()`` on the post-SPMD module reports *per-device* FLOPs and
bytes; collective bytes are parsed per-device from the HLO. The f32->bf16
correction: gradient-sync collectives were lowered in f32 on this CPU
backend (XLA bug, see launch/dryrun.py) but are bf16 on the TPU target, so
f32 collective bytes in *train* steps are halved.

Outputs experiments/roofline.csv and a markdown table; also computes
MODEL_FLOPS = 6*N(_active)*D and the usefulness ratio MODEL/HLO FLOPs.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}


def analyze(rec: dict) -> dict:
    chips = rec["chips"]
    ct = rec.get("cost_true")
    if ct:
        # scan-aware extrapolated costs (launch/cost_extrapolate.py);
        # wire bytes (ring realization per op) when available
        flops = ct["flops"] or 0.0
        bytes_acc = ct["bytes_accessed"] or 0.0
        coll = ct.get("coll_wire", ct["coll_total"])
        f32 = ct.get("coll_wire_f32", ct["coll_f32"])
    else:
        flops = rec["cost"]["flops"] or 0.0
        bytes_acc = rec["cost"]["bytes_accessed"] or 0.0
        coll = rec["collectives"]["total_bytes"]
        f32 = rec["collectives"].get("by_dtype", {}).get("f32", 0)
    # f32 -> bf16 exchange correction for the CPU-lowered gradient sync
    if rec["step"] == "train":
        coll -= f32 / 2
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    # model flops: train ~ 6ND (fwd+bwd); inference ~ 2ND
    n = rec["active_params"]
    d_tokens = SHAPE_TOKENS[rec["shape"]]
    mult = 6.0 if rec["step"] == "train" else 2.0
    model_flops_global = mult * n * d_tokens
    model_flops_chip = model_flops_global / chips
    useful = model_flops_chip / flops if flops else float("nan")

    step_time = max(terms.values())          # perfectly-overlapped bound
    mfu = model_flops_chip / (step_time * PEAK_FLOPS) if step_time else 0.0
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "step", "fsdp")},
        "cost_true": bool(ct),
        "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": model_flops_chip,
        "hlo_flops_per_chip": flops,
        "useful_ratio": useful,
        "bound_step_s": step_time,
        "mfu_bound": mfu,
        "coll_bytes_per_chip": coll,
        "temp_bytes_per_chip_gib": (rec["memory"]["temp_bytes"] or 0) / 2**30,
    }


def load_all(dirname: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rows.append(analyze(json.load(f)))
    return rows


def write_csv(rows, path="experiments/roofline.csv"):
    if not rows:
        return
    keys = list(rows[0])
    with open(path, "w") as f:
        f.write(",".join(keys) + "\n")
        for r in rows:
            f.write(",".join(f"{r[k]:.4e}" if isinstance(r[k], float)
                             else str(r[k]) for k in keys) + "\n")


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | MFU-bound |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['mfu_bound'] * 100:.1f}% |")
    return "\n".join(lines)


def run() -> list[dict]:
    rows = load_all()
    write_csv(rows)
    out = []
    for r in rows:
        out.append({
            "name": f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
            "us_per_call": round(r["bound_step_s"] * 1e6, 1),
            "derived": (f"dom={r['dominant']},useful={r['useful_ratio']:.2f},"
                        f"mfu<={r['mfu_bound'] * 100:.1f}%"),
        })
    return out


if __name__ == "__main__":
    rows = load_all()
    write_csv(rows)
    print(markdown_table(rows))
