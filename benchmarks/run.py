"""Benchmark harness: one module per paper table. Prints
``name,us_per_call,derived`` CSV rows (harness contract).

  table 2/6 (all-reduce schemes + scaling)   -> benchmarks.allreduce
  table 5   (LS / batch-size-control ablation) -> benchmarks.convergence
  table 1/6 (time-to-train + throughput model) -> benchmarks.throughput
  roofline  (from dry-run artifacts, if present) -> benchmarks.roofline
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> None:
    from benchmarks import allreduce, convergence, roofline, throughput

    rows = []
    for mod in (allreduce, throughput, convergence, roofline):
        try:
            rows.extend(mod.run())
        except Exception as e:  # noqa: BLE001
            rows.append({"name": f"{mod.__name__}_ERROR",
                         "us_per_call": -1, "derived": repr(e)[:80]})
    print("name,us_per_call,derived")
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == '__main__':
    main()
