"""Render §Dry-run and §Roofline markdown tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""

from __future__ import annotations

import glob
import json

from benchmarks import roofline as R


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | step | lower s | compile s | temp/chip GiB "
        "| coll GiB/chip | coll ops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        coll = rec["collectives"]
        ops = ",".join(f"{k.split('-')[-1][:4]}:{v['count']}"
                       for k, v in coll.items()
                       if isinstance(v, dict) and v.get("count"))
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {rec['step']}"
            f" | {rec['lower_s']} | {rec['compile_s']}"
            f" | {(rec['memory']['temp_bytes'] or 0) / 2**30:.2f}"
            f" | {coll['total_bytes'] / 2**30:.2f} | {ops} |")
    return "\n".join(lines)


def main():
    raw = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(path) as f:
            raw.append(json.load(f))
    print(f"## §Dry-run ({len(raw)} combinations)\n")
    print(dryrun_table(raw))
    print("\n## §Roofline\n")
    rows = R.load_all()
    R.write_csv(rows)
    print(R.markdown_table(rows))


if __name__ == "__main__":
    main()
