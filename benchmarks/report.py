"""Render §Dry-run and §Roofline markdown tables from the dry-run JSONs.

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md

``--metrics <run.jsonl>`` instead summarizes a training run's metrics
JSONL (repro.obs, docs/observability.md) into the harness CSV contract
(``name,us_per_call,derived``): mean per-step phase durations from the
``step_phases`` rows plus every instrument in the final summary row.
"""

from __future__ import annotations

import argparse
import glob
import json

from benchmarks import roofline as R


def dryrun_table(records) -> str:
    lines = [
        "| arch | shape | mesh | step | lower s | compile s | temp/chip GiB "
        "| coll GiB/chip | coll ops |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        coll = rec["collectives"]
        ops = ",".join(f"{k.split('-')[-1][:4]}:{v['count']}"
                       for k, v in coll.items()
                       if isinstance(v, dict) and v.get("count"))
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | {rec['step']}"
            f" | {rec['lower_s']} | {rec['compile_s']}"
            f" | {(rec['memory']['temp_bytes'] or 0) / 2**30:.2f}"
            f" | {coll['total_bytes'] / 2**30:.2f} | {ops} |")
    return "\n".join(lines)


def metrics_rows(path: str) -> list[dict]:
    """Summarize a metrics JSONL (rotation-aware) into harness CSV rows.

    Per-phase means come from the ``step_phases`` rows; everything else
    from the final ``summary`` snapshot -- time histograms render their
    mean in µs, counters/gauges carry their value in ``derived`` (µs
    column 0). ``derived`` never contains commas (CSV contract).
    """
    from repro.obs.sink import read_run

    rows = read_run(path)
    out = []
    phases = [r for r in rows if r.get("metric") == "step_phases"]
    if phases:
        n = len(phases)
        wall = sum(p["wall_s"] for p in phases)
        out.append({"name": "obs/step_wall",
                    "us_per_call": round(wall / n * 1e6, 1),
                    "derived": f"steps={n}"})
        for ph in ("data", "dispatch", "sync_wait", "log", "checkpoint"):
            tot = sum(p["phases"].get(ph, 0.0) for p in phases)
            out.append({"name": f"obs/phase_{ph}",
                        "us_per_call": round(tot / n * 1e6, 1),
                        "derived": (f"frac={tot / wall:.3f}" if wall
                                    else "")})
    summaries = [r for r in rows if r.get("kind") == "summary"]
    if summaries:
        for name, snap in summaries[-1]["metrics"].items():
            kind = snap.get("type")
            if kind == "histogram":
                # mean in µs is only meaningful for the *_s time
                # histograms, but count/derived stay correct regardless
                out.append({"name": f"obs/{name}",
                            "us_per_call": round(snap["mean"] * 1e6, 1),
                            "derived": f"count={snap['count']}"})
            elif kind == "counter":
                out.append({"name": f"obs/{name}", "us_per_call": 0,
                            "derived": f"count={int(snap['value'])}"})
            elif kind == "gauge":
                out.append({"name": f"obs/{name}", "us_per_call": 0,
                            "derived": f"value={snap['value']:g}"})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--metrics", default=None,
                    help="summarize this metrics JSONL into "
                         "name,us_per_call,derived CSV rows instead of "
                         "rendering the dry-run report")
    args = ap.parse_args()
    if args.metrics:
        print("name,us_per_call,derived")
        for r in metrics_rows(args.metrics):
            print(f"{r['name']},{r['us_per_call']},{r['derived']}")
        return
    raw = []
    for path in sorted(glob.glob("experiments/dryrun/*.json")):
        with open(path) as f:
            raw.append(json.load(f))
    print(f"## §Dry-run ({len(raw)} combinations)\n")
    print(dryrun_table(raw))
    print("\n## §Roofline\n")
    rows = R.load_all()
    R.write_csv(rows)
    print(R.markdown_table(rows))


if __name__ == "__main__":
    main()
