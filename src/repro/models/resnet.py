"""ResNet-50 (He et al. [7]), v1.5 bottleneck, faithful to the paper:

- He fan-in init; the last BN gamma of every residual block is zero-init
  (You et al. [10], which §3.2 cites for initialization).
- BN "without moving average": train-time batch statistics, synchronized
  across data-parallel replicas in fp32; eval statistics come from a
  calibration pass (``collect_stats``).
- Mixed precision: params are fp32 masters, fwd/bwd runs in ``compute_dtype``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.nn import layers as L


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    stage_sizes: tuple[int, ...] = (3, 4, 6, 3)      # ResNet-50
    width: int = 64
    num_classes: int = 1000
    compute_dtype: Any = jnp.bfloat16
    image_size: int = 224

    @staticmethod
    def resnet50(**kw):
        return ResNetConfig(**kw)

    @staticmethod
    def tiny(**kw):
        """Reduced variant for CPU tests: 2 stages x 1 block, width 8."""
        kw.setdefault("stage_sizes", (1, 1))
        kw.setdefault("width", 8)
        kw.setdefault("num_classes", 10)
        kw.setdefault("image_size", 32)
        return ResNetConfig(**kw)


def _bottleneck_init(key, cin, inner, cout):
    k = jax.random.split(key, 4)
    p = {
        "conv1": L.conv_init(k[0], 1, 1, cin, inner),
        "bn1": L.batchnorm_init(inner),
        "conv2": L.conv_init(k[1], 3, 3, inner, inner),
        "bn2": L.batchnorm_init(inner),
        "conv3": L.conv_init(k[2], 1, 1, inner, cout),
        "bn3": L.batchnorm_init(cout, zero_gamma=True),
    }
    if cin != cout:
        p["proj"] = L.conv_init(k[3], 1, 1, cin, cout)
        p["bn_proj"] = L.batchnorm_init(cout)
    return p


def init(key, cfg: ResNetConfig):
    keys = jax.random.split(key, 2 + len(cfg.stage_sizes) * max(cfg.stage_sizes))
    params = {
        "stem": {"conv": L.conv_init(keys[0], 7, 7, 3, cfg.width),
                 "bn": L.batchnorm_init(cfg.width)},
        "stages": [],
    }
    cin = cfg.width
    ki = 1
    for s, nblocks in enumerate(cfg.stage_sizes):
        inner = cfg.width * (2 ** s)
        cout = inner * 4
        blocks = []
        for b in range(nblocks):
            blocks.append(_bottleneck_init(keys[ki], cin, inner, cout))
            ki += 1
            cin = cout
        params["stages"].append(blocks)
    params["head"] = L.dense_init(keys[-1], cin, cfg.num_classes)
    return params


def _bottleneck(p, x, stride, *, dp_axes, stats, collect):
    sts = {}

    def bn(name, h, zero_ok=False):
        st = None if stats is None else stats[name]
        out = L.batchnorm(p[name], h, stats=st, dp_axes=dp_axes,
                          return_stats=collect)
        if collect:
            out, s = out
            sts[name] = s
        return out

    h = jax.nn.relu(bn("bn1", L.conv(p["conv1"], x, 1)))
    h = jax.nn.relu(bn("bn2", L.conv(p["conv2"], h, stride)))   # v1.5 stride
    h = bn("bn3", L.conv(p["conv3"], h, 1))
    if "proj" in p:
        sc = bn("bn_proj", L.conv(p["proj"], x, stride))
    else:
        sc = x
    out = jax.nn.relu(h + sc)
    return (out, sts) if collect else out


def apply(params, images, cfg: ResNetConfig, *, dp_axes=(), stats=None,
          collect_stats=False):
    """images: (B, H, W, 3) in [0, 1]-ish normalized floats.

    ``stats``: pytree of per-BN (mean, var) for eval; ``collect_stats``
    returns (logits, stats_pytree) -- the calibration pass of
    "BN without moving average".
    """
    p = L.cast(params, cfg.compute_dtype)
    x = images.astype(cfg.compute_dtype)
    all_stats = {"stem": {}, "stages": []}

    st = None if stats is None else stats["stem"].get("bn")
    h = L.conv(p["stem"]["conv"], x, 2)
    out = L.batchnorm(p["stem"]["bn"], h, stats=st, dp_axes=dp_axes,
                      return_stats=collect_stats)
    if collect_stats:
        out, s = out
        all_stats["stem"]["bn"] = s
    h = jax.nn.relu(out)
    h = L.max_pool(h, 3, 2)

    for si, blocks in enumerate(p["stages"]):
        stage_stats = []
        for bi, bp in enumerate(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            bst = None if stats is None else stats["stages"][si][bi]
            out = _bottleneck(bp, h, stride, dp_axes=dp_axes, stats=bst,
                              collect=collect_stats)
            if collect_stats:
                h, s = out
                stage_stats.append(s)
            else:
                h = out
        all_stats["stages"].append(stage_stats)

    h = L.global_avg_pool(h).astype(jnp.float32)
    logits = L.dense(L.cast(params["head"], jnp.float32), h)
    if collect_stats:
        return logits, all_stats
    return logits


def num_params(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
