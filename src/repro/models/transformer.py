"""Unified decoder stack covering all assigned architectures.

A model is a cycled ``pattern`` of layer kinds over ``n_layers``:

    attn   -- global causal self-attention (GQA/MQA/MHA)
    local  -- sliding-window self-attention (window = cfg.window)
    cross  -- cross-attention to vision/audio embeddings (VLM)
    ssd    -- Mamba-2 state-space block (no separate MLP when mlp='none')
    rglru  -- RG-LRU recurrent block (RecurrentGemma)

Layers whose index falls in the repeated region are *scanned*
(``lax.scan`` over stacked params) so the HLO stays compact for 126-layer
models; ``n_layers % len(pattern)`` leading layers plus ``first_dense``
MoE-exempt layers form an unscanned prefix.

Three entry points:
    forward(params, tokens, ...)                 -> logits            (train)
    prefill(params, tokens, ...)                 -> (last_logits, cache)
    decode_step(params, token, cache, index,...) -> (logits, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import attention as A
from repro.nn import layers as L
from repro.nn import moe as M
from repro.nn import rglru as R
from repro.nn import ssm as S


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("attn",)
    mlp: str = "dense"                   # dense | moe | none
    n_experts: int = 0
    top_k: int = 0
    first_dense: int = 0                 # leading layers forced dense-MLP
    act: str = "silu"
    gated_mlp: bool = True               # False: plain 2-matrix FFN (musicgen)
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    qk_norm: bool = False
    post_norm: bool = False              # gemma2 post-block norms
    logit_softcap: float | None = None
    attn_softcap: float | None = None
    window: int | None = None
    rope_theta: float = 10000.0
    embed_scale: bool = False            # gemma: embeds * sqrt(d)
    tie_embeddings: bool = True
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_unroll: bool = False
    moe_capacity_factor: float = 1.25
    q_chunk: int = 1024                  # 0 = unchunked attention
    q_chunk_unroll: bool = False
    cross_kv_dim: int | None = None
    vision_tokens: int = 0
    compute_dtype: Any = jnp.bfloat16
    remat: bool = False
    scan_blocks: bool = True             # False: unroll all layers (cost
                                         # analysis; XLA excludes while-loop
                                         # bodies from cost_analysis)
    # citation / provenance
    source: str = ""

    # ------------------------------------------------------------------
    def kinds(self) -> tuple[str, ...]:
        return tuple(self.pattern[i % len(self.pattern)]
                     for i in range(self.n_layers))

    @property
    def n_prefix(self) -> int:
        if not self.scan_blocks:
            return self.n_layers
        rest = self.n_layers - self.first_dense
        return self.first_dense + rest % len(self.pattern)

    @property
    def n_blocks(self) -> int:
        return (self.n_layers - self.n_prefix) // len(self.pattern)

    def attn_cfg(self, kind: str) -> A.AttnConfig:
        return A.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.head_dim,
            rope_theta=self.rope_theta, qk_norm=self.qk_norm,
            attn_softcap=self.attn_softcap,
            window=self.window if kind == "local" else None,
            cross_kv_dim=self.cross_kv_dim if kind == "cross" else None,
            query_scale=self.head_dim ** -0.5)

    def ssd_cfg(self) -> S.SSDConfig:
        return S.SSDConfig(d_model=self.d_model, d_state=self.ssm_state,
                           head_dim=self.ssm_head_dim, chunk=self.ssm_chunk,
                           unroll_scan=self.ssm_unroll)

    def rglru_cfg(self) -> R.RGLRUConfig:
        return R.RGLRUConfig(d_model=self.d_model)

    def moe_cfg(self) -> M.MoEConfig:
        return M.MoEConfig(d_model=self.d_model, d_ff=self.d_ff,
                           n_experts=self.n_experts, top_k=self.top_k,
                           capacity_factor=self.moe_capacity_factor,
                           act=self.act)

    def num_params(self) -> int:
        """Analytic parameter count (no allocation)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        total = v * d                                     # embedding
        if not self.tie_embeddings:
            total += v * d
        per_kind = {}
        o = self.n_heads * self.head_dim * d
        per_kind["attn"] = per_kind["local"] = d * self.n_heads * self.head_dim \
            + 2 * d * self.n_kv_heads * self.head_dim + o
        per_kind["cross"] = d * self.n_heads * self.head_dim + 2 * (
            (self.cross_kv_dim or d) * self.n_kv_heads * self.head_dim) + o
        sc = self.ssd_cfg()
        per_kind["ssd"] = d * (2 * sc.d_inner + 2 * sc.d_state + sc.n_heads) \
            + sc.d_inner * d
        per_kind["rglru"] = 5 * d * d                     # in x2, gates x2, out
        n_mats = 3 if self.gated_mlp else 2
        mlp_dense = n_mats * d * f
        mlp_moe = self.n_experts * 3 * d * f + d * self.n_experts
        mlp_moe_dense = 3 * d * f * max(self.top_k, 1)    # first_dense layers
        for i, k in enumerate(self.kinds()):
            total += per_kind[k]
            if self.mlp == "none":
                continue
            if self.mlp == "moe":
                total += mlp_moe if i >= self.first_dense else mlp_moe_dense
            else:
                total += mlp_dense
        return total

    def active_params(self) -> int:
        """MoE: params touched per token (for MODEL_FLOPS = 6*N_active*D)."""
        if self.mlp != "moe":
            return self.num_params()
        d, f = self.d_model, self.d_ff
        full = self.num_params()
        inactive = (self.n_experts - self.top_k) * 3 * d * f * (
            self.n_layers - self.first_dense)
        return full - inactive


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(cfg, dim=None):
    dim = dim or cfg.d_model
    return (L.rmsnorm_init(dim) if cfg.norm == "rmsnorm"
            else L.layernorm_init(dim))


def _norm(cfg, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rmsnorm" else L.layernorm(p, x)


def _layer_init(key, cfg: ArchConfig, kind: str, layer_idx: int):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {"pre_norm": _norm_init(cfg)}
    if kind in ("attn", "local", "cross"):
        p["mixer"] = A.attn_init(k1, cfg.attn_cfg(kind))
    elif kind == "ssd":
        p["mixer"] = S.ssd_init(k1, cfg.ssd_cfg())
    elif kind == "rglru":
        p["mixer"] = R.rglru_init(k1, cfg.rglru_cfg())
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        p["post_mixer_norm"] = _norm_init(cfg)
    if cfg.mlp != "none":
        p["mlp_norm"] = _norm_init(cfg)
        if cfg.mlp == "moe" and layer_idx >= cfg.first_dense:
            p["mlp"] = M.moe_init(k2, cfg.moe_cfg())
        else:
            # dense layers in MoE models use the arch's dense d_ff heuristic:
            # experts' f * top_k to keep activated compute comparable
            f = cfg.d_ff if cfg.mlp != "moe" else cfg.d_ff * max(cfg.top_k, 1)
            p["mlp"] = L.mlp_init(k2, cfg.d_model, f, gated=cfg.gated_mlp,
                                  act=cfg.act)
        if cfg.post_norm:
            p["post_mlp_norm"] = _norm_init(cfg)
    return p


def init(key, cfg: ArchConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    kinds = cfg.kinds()
    params = {
        "embed": L.embedding_init(keys[0], cfg.vocab, cfg.d_model),
        "final_norm": _norm_init(cfg),
        "prefix": [
            _layer_init(keys[1 + i], cfg, kinds[i], i)
            for i in range(cfg.n_prefix)
        ],
    }
    if not cfg.tie_embeddings:
        params["unembed"] = {"kernel": jax.random.normal(
            keys[-1], (cfg.d_model, cfg.vocab), jnp.float32) * 0.02}
    if cfg.n_blocks > 0:
        base = cfg.n_prefix

        def one_block(k):
            ks = jax.random.split(k, len(cfg.pattern))
            return [
                _layer_init(ks[j], cfg, kinds[base + j], base + j)
                for j in range(len(cfg.pattern))
            ]
        block_keys = jax.random.split(keys[-2], cfg.n_blocks)
        params["blocks"] = jax.vmap(one_block)(block_keys)
    return params


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def _apply_layer(p, x, cfg: ArchConfig, kind: str, *, vision=None):
    """Full-seq layer. Returns (x, aux)."""
    h = _norm(cfg, p["pre_norm"], x)
    if kind in ("attn", "local"):
        h = A.self_attention(p["mixer"], h, cfg.attn_cfg(kind),
                             q_chunk=cfg.q_chunk, unroll=cfg.q_chunk_unroll)
    elif kind == "cross":
        h = A.cross_attention(p["mixer"], h, vision, cfg.attn_cfg(kind))
    elif kind == "ssd":
        h = S.ssd_apply(p["mixer"], h, cfg.ssd_cfg())
    elif kind == "rglru":
        h = R.rglru_apply(p["mixer"], h, cfg.rglru_cfg())
    if cfg.post_norm:
        h = _norm(cfg, p["post_mixer_norm"], h)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    if cfg.mlp != "none":
        h = _norm(cfg, p["mlp_norm"], x)
        if "router" in p["mlp"]:
            h, aux = M.moe_apply(p["mlp"], h, cfg.moe_cfg())
        else:
            h = L.mlp(p["mlp"], h, act=cfg.act)
        if cfg.post_norm:
            h = _norm(cfg, p["post_mlp_norm"], h)
        x = x + h
    return x, aux


def _embed_in(params, cfg, tokens):
    x = L.embed(params["embed"], tokens, cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.compute_dtype)
    return x


def _logits_out(params, cfg, x):
    x = _norm(cfg, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x)
    else:
        logits = x @ params["unembed"]["kernel"].astype(x.dtype)
    logits = logits.astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(params, tokens, cfg: ArchConfig, *, vision=None):
    """tokens: (B, S) int32 -> logits (B, S, V) fp32. aux: scalar MoE loss."""
    x = _embed_in(params, cfg, tokens)
    kinds = cfg.kinds()
    aux_total = jnp.zeros((), jnp.float32)

    prefix_layer = (jax.checkpoint(_apply_layer, static_argnums=(2, 3))
                    if cfg.remat else _apply_layer)
    for i, p in enumerate(params["prefix"]):
        x, aux = prefix_layer(p, x, cfg, kinds[i], vision=vision)
        aux_total += aux

    if cfg.n_blocks > 0:
        base = cfg.n_prefix
        block_kinds = kinds[base: base + len(cfg.pattern)]

        def body(carry, bp):
            x, aux_acc = carry
            for j, kind in enumerate(block_kinds):
                x, aux = _apply_layer(bp[j], x, cfg, kind, vision=vision)
                aux_acc = aux_acc + aux
            return (x, aux_acc), None

        if cfg.remat:
            body = jax.checkpoint(body)
        (x, aux_total), _ = lax.scan(body, (x, aux_total), params["blocks"])

    return _logits_out(params, cfg, x), aux_total


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ArchConfig, kind: str, batch: int, cache_len: int,
                 dtype=jnp.bfloat16):
    if kind in ("attn", "cross"):
        return A.init_kv_cache(batch, cache_len, cfg.attn_cfg(kind), dtype)
    if kind == "local":
        return A.init_kv_cache(batch, min(cfg.window, cache_len),
                               cfg.attn_cfg(kind), dtype)
    if kind == "ssd":
        return S.ssd_init_state(batch, cfg.ssd_cfg(), dtype)
    if kind == "rglru":
        return R.rglru_init_state(batch, cfg.rglru_cfg(), dtype)
    raise ValueError(kind)


def init_cache(cfg: ArchConfig, batch: int, cache_len: int,
               dtype=jnp.bfloat16):
    kinds = cfg.kinds()
    cache = {"prefix": [
        _layer_cache(cfg, kinds[i], batch, cache_len, dtype)
        for i in range(cfg.n_prefix)
    ]}
    if cfg.n_blocks > 0:
        base = cfg.n_prefix
        one = [
            _layer_cache(cfg, kinds[base + j], batch, cache_len, dtype)
            for j in range(len(cfg.pattern))
        ]
        cache["blocks"] = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (cfg.n_blocks,) + l.shape), one)
    return cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _decode_layer(p, x, c, index, cfg: ArchConfig, kind: str):
    h = _norm(cfg, p["pre_norm"], x)
    if kind in ("attn", "local"):
        h, c = A.decode_self_attention(p["mixer"], h, c, index,
                                       cfg.attn_cfg(kind))
    elif kind == "cross":
        # decode-time cross-attn reads the prefilled vision KV cache
        h, c = _decode_cross(p["mixer"], h, c, cfg.attn_cfg(kind))
    elif kind == "ssd":
        h, c = S.ssd_decode_step(p["mixer"], h, c, cfg.ssd_cfg())
    elif kind == "rglru":
        h, c = R.rglru_decode_step(p["mixer"], h, c, cfg.rglru_cfg())
    if cfg.post_norm:
        h = _norm(cfg, p["post_mixer_norm"], h)
    x = x + h
    if cfg.mlp != "none":
        h = _norm(cfg, p["mlp_norm"], x)
        if "router" in p["mlp"]:
            h, _ = M.moe_apply(p["mlp"], h, cfg.moe_cfg())
        else:
            h = L.mlp(p["mlp"], h, act=cfg.act)
        if cfg.post_norm:
            h = _norm(cfg, p["post_mlp_norm"], h)
        x = x + h
    return x, c


def _decode_cross(p, x, cache, acfg: A.AttnConfig):
    """Cross-attention during decode: static K/V from the vision cache."""
    B = x.shape[0]
    q = (x @ p["q"]["kernel"].astype(x.dtype)).reshape(
        B, 1, acfg.n_heads, acfg.head_dim)
    if acfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
    mask = jnp.ones((B, 1, cache["k"].shape[1]), bool)
    out = A._sdpa(q, cache["k"].astype(x.dtype), cache["v"].astype(x.dtype),
                  mask, acfg)
    return out @ p["o"]["kernel"].astype(x.dtype), cache


def decode_step(params, token, cache, index, cfg: ArchConfig):
    """token: (B, 1) int32; index: scalar int32 absolute position.
    Returns (logits (B, 1, V), new_cache)."""
    x = _embed_in(params, cfg, token)
    kinds = cfg.kinds()

    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        x, c = _decode_layer(p, x, cache["prefix"][i], index, cfg, kinds[i])
        new_prefix.append(c)
    new_cache = {"prefix": new_prefix}

    if cfg.n_blocks > 0:
        base = cfg.n_prefix
        block_kinds = kinds[base: base + len(cfg.pattern)]

        def body(x, blk):
            bp, bc = blk
            new_c = []
            for j, kind in enumerate(block_kinds):
                x, cj = _decode_layer(bp[j], x, bc[j], index, cfg, kind)
                new_c.append(cj)
            return x, new_c

        x, new_blocks = lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = new_blocks

    return _logits_out(params, cfg, x), new_cache


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------

def _prefill_layer(p, x, index_len, cache_len, cfg: ArchConfig, kind: str,
                   vision=None, dtype=jnp.bfloat16):
    h = _norm(cfg, p["pre_norm"], x)
    if kind in ("attn", "local"):
        acfg = cfg.attn_cfg(kind)
        clen = cache_len if kind == "attn" else min(cfg.window, cache_len)
        c = A.prefill_kv_cache(p["mixer"], h, acfg, clen, dtype=dtype)
        h = A.self_attention(p["mixer"], h, acfg, q_chunk=cfg.q_chunk,
                             unroll=cfg.q_chunk_unroll)
    elif kind == "cross":
        acfg = cfg.attn_cfg(kind)
        kv = vision.astype(x.dtype)
        k = (kv @ p["mixer"]["k"]["kernel"].astype(x.dtype)).reshape(
            kv.shape[0], -1, acfg.n_kv_heads, acfg.head_dim)
        v = (kv @ p["mixer"]["v"]["kernel"].astype(x.dtype)).reshape(
            kv.shape[0], -1, acfg.n_kv_heads, acfg.head_dim)
        if acfg.qk_norm:
            k = L.rmsnorm(p["mixer"]["k_norm"], k)
        c = {"k": k.astype(dtype), "v": v.astype(dtype)}
        h = A.cross_attention(p["mixer"], h, vision, acfg)
    elif kind == "ssd":
        h, c = S.ssd_apply(p["mixer"], h, cfg.ssd_cfg(), return_state=True)
    elif kind == "rglru":
        h, c = R.rglru_apply(p["mixer"], h, cfg.rglru_cfg(), return_state=True)
    if cfg.post_norm:
        h = _norm(cfg, p["post_mixer_norm"], h)
    x = x + h
    if cfg.mlp != "none":
        h = _norm(cfg, p["mlp_norm"], x)
        if "router" in p["mlp"]:
            h, _ = M.moe_apply(p["mlp"], h, cfg.moe_cfg())
        else:
            h = L.mlp(p["mlp"], h, act=cfg.act)
        if cfg.post_norm:
            h = _norm(cfg, p["post_mlp_norm"], h)
        x = x + h
    return x, c


def prefill(params, tokens, cfg: ArchConfig, *, vision=None, cache_len=None,
            cache_dtype=jnp.bfloat16):
    """Process the prompt, return (last-position logits, cache)."""
    B, S = tokens.shape
    cache_len = cache_len or S
    x = _embed_in(params, cfg, tokens)
    kinds = cfg.kinds()

    new_prefix = []
    for i, p in enumerate(params["prefix"]):
        x, c = _prefill_layer(p, x, S, cache_len, cfg, kinds[i],
                              vision=vision, dtype=cache_dtype)
        new_prefix.append(c)
    cache = {"prefix": new_prefix}

    if cfg.n_blocks > 0:
        base = cfg.n_prefix
        block_kinds = kinds[base: base + len(cfg.pattern)]

        def body(x, bp):
            cs = []
            for j, kind in enumerate(block_kinds):
                x, c = _prefill_layer(bp[j], x, S, cache_len, cfg, kind,
                                      vision=vision, dtype=cache_dtype)
                cs.append(c)
            return x, cs

        if cfg.remat:
            body = jax.checkpoint(body)
        x, blocks_cache = lax.scan(body, x, params["blocks"])
        cache["blocks"] = blocks_cache

    logits = _logits_out(params, cfg, x[:, -1:])
    return logits, cache
