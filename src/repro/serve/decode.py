"""Serving: batched prefill + autoregressive decode over the KV cache.

``make_serve_step`` builds the jitted one-token step that the decode dry-run
shapes lower (decode_32k, long_500k). ``generate`` runs a full
prefill-then-decode loop (greedy or temperature sampling) for the examples.
``RequestBatcher`` pads/packs incoming prompts into fixed serving shapes so
every request reuses the same compiled program.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T


def make_serve_step(cfg: T.ArchConfig):
    """(params, token (B,1), cache, index) -> (next_token, logits, cache)."""
    @jax.jit
    def serve_step(params, token, cache, index):
        logits, cache = T.decode_step(params, token, cache, index, cfg)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        return nxt, logits, cache
    return serve_step


def generate(params, prompts, cfg: T.ArchConfig, *, max_new_tokens=16,
             vision=None, cache_len=None, temperature=0.0, key=None):
    """prompts: (B, S) int32. Returns (B, max_new_tokens) int32."""
    B, S = prompts.shape
    cache_len = cache_len or (S + max_new_tokens)
    logits, cache = jax.jit(functools.partial(
        T.prefill, cfg=cfg, cache_len=cache_len))(params, prompts,
                                                  vision=vision)
    step = make_serve_step(cfg)

    def sample(lg, k):
        if temperature <= 0.0:
            return jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            k, lg[:, -1] / temperature).astype(jnp.int32)[:, None]

    key = key if key is not None else jax.random.key(0)
    tok = sample(logits, key)
    out = [tok]
    for t in range(1, max_new_tokens):
        key, sub = jax.random.split(key)
        nxt, logits, cache = step(params, tok, cache, S + t - 1)
        tok = sample(logits, sub) if temperature > 0 else nxt
        out.append(tok)
    return jnp.concatenate(out, axis=1)


@dataclasses.dataclass
class RequestBatcher:
    """Packs variable-length prompts into a fixed (batch, seq) shape.

    Real serving systems (vLLM-style) continuously batch; this is the
    synchronous version: collect up to ``batch_size`` requests, left-pad to
    ``seq_len``, run one generate() call, slice results back out.
    """
    batch_size: int
    seq_len: int
    pad_id: int = 0

    def pack(self, prompts: list[list[int]]):
        if len(prompts) > self.batch_size:
            raise ValueError(f"got {len(prompts)} > batch {self.batch_size}")
        n = len(prompts)
        buf = np.full((self.batch_size, self.seq_len), self.pad_id, np.int32)
        lens = np.zeros((self.batch_size,), np.int32)
        for i, prom in enumerate(prompts):
            prom = prom[-self.seq_len:]
            buf[i, self.seq_len - len(prom):] = prom     # left-pad
            lens[i] = len(prom)
        return jnp.asarray(buf), jnp.asarray(lens), n

    def unpack(self, generated, n_real: int):
        return [list(np.asarray(generated[i])) for i in range(n_real)]
