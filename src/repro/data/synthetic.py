"""Deterministic synthetic datasets (no ImageNet in this container).

Two generators:

* ``SyntheticImageNet`` -- class-conditional Gaussian-blob images: each of
  the K classes has a fixed random template; a sample is template + noise.
  Linear-separable enough that ResNet training shows real convergence
  signal (benchmarks/convergence.py reproduces the paper's Table-5
  *relative* effects: LS and batch-size control vs baseline).
* ``SyntheticTokens`` -- order-2 Markov token stream with a fixed random
  transition matrix; gives language-model training a learnable signal.

Both are stateless: batch ``i`` is a pure function of (seed, i), so any
worker can produce its shard without coordination -- the same property a
sharded tf.data/grain pipeline provides on the real cluster.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticImageNet:
    num_classes: int = 1000
    image_size: int = 224
    seed: int = 0
    noise: float = 0.8

    def templates(self, downsample: int = 8):
        """Fixed per-class low-res templates (deterministic in seed)."""
        k = jax.random.key(self.seed)
        hw = self.image_size // downsample
        return jax.random.normal(k, (self.num_classes, hw, hw, 3))

    def batch(self, index: int, batch_size: int):
        """Batch ``index`` -> (images (B,H,W,3) fp32, labels (B,) int32)."""
        k = jax.random.fold_in(jax.random.key(self.seed + 1), index)
        k1, k2 = jax.random.split(k)
        labels = jax.random.randint(k1, (batch_size,), 0, self.num_classes)
        tmpl = self.templates()[labels]                     # (B, hw, hw, 3)
        up = jnp.repeat(jnp.repeat(tmpl, self.image_size // tmpl.shape[1], 1),
                        self.image_size // tmpl.shape[2], 2)
        imgs = up + self.noise * jax.random.normal(
            k2, (batch_size, self.image_size, self.image_size, 3))
        return imgs, labels


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int = 32000
    seed: int = 0
    order_dim: int = 64     # rank of the transition structure

    def batch(self, index: int, batch_size: int, seq_len: int):
        """(tokens, labels): labels = next token (shifted).

        Sequential structure: with prob 0.5 the next token is the
        deterministic map f(prev) = (prev*7 + 11) % V, else fresh random --
        a first-order Markov chain an LM can actually learn.
        """
        k = jax.random.fold_in(jax.random.key(self.seed + 2), index)
        k1, k2, k3 = jax.random.split(k, 3)
        rnd = jax.random.randint(k1, (batch_size, seq_len + 1), 0, self.vocab)
        use_det = jax.random.bernoulli(k2, 0.5, (batch_size, seq_len))

        def step(prev, inp):
            r, b = inp
            nxt = jnp.where(b, (prev * 7 + 11) % self.vocab, r)
            return nxt, nxt

        _, rest = jax.lax.scan(
            step, rnd[:, 0], (rnd[:, 1:].T, use_det.T))
        tokens = jnp.concatenate([rnd[:, :1], rest.T], axis=1)
        return tokens[:, :-1].astype(jnp.int32), tokens[:, 1:].astype(jnp.int32)
