"""Image augmentation ops in pure JAX (paper §3.2 lists NNL's pipeline:
padding, scaling, rotations, resizing, distortion, flipping, brightness
adjustment, contrast adjustment, and noising).

Every op is jit-able and batched (B, H, W, C), driven by a PRNG key, so the
input pipeline runs on-device and its cost is visible in the step profile.
Rotation/scaling/distortion are implemented as a single affine resample
(bilinear gather) -- one memory pass for the geometric group.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def random_flip(key, images):
    flip = jax.random.bernoulli(key, 0.5, (images.shape[0],))
    return jnp.where(flip[:, None, None, None], images[:, :, ::-1], images)


def random_brightness(key, images, max_delta=0.2):
    d = jax.random.uniform(key, (images.shape[0], 1, 1, 1),
                           minval=-max_delta, maxval=max_delta)
    return images + d


def random_contrast(key, images, lower=0.8, upper=1.2):
    f = jax.random.uniform(key, (images.shape[0], 1, 1, 1),
                           minval=lower, maxval=upper)
    mean = images.mean(axis=(1, 2), keepdims=True)
    return (images - mean) * f + mean


def random_noise(key, images, std=0.02):
    return images + std * jax.random.normal(key, images.shape, images.dtype)


def _affine_resample(images, mats, out_hw):
    """Batched affine warp with bilinear sampling.

    mats: (B, 2, 3) mapping output pixel coords -> input coords.
    """
    B, H, W, C = images.shape
    oh, ow = out_hw
    ys, xs = jnp.meshgrid(jnp.arange(oh, dtype=jnp.float32),
                          jnp.arange(ow, dtype=jnp.float32), indexing="ij")
    grid = jnp.stack([ys.ravel(), xs.ravel(), jnp.ones(oh * ow)], 0)  # (3, P)
    src = jnp.einsum("bij,jp->bip", mats, grid)                        # (B,2,P)
    sy, sx = src[:, 0], src[:, 1]
    y0 = jnp.floor(sy)
    x0 = jnp.floor(sx)
    wy = sy - y0
    wx = sx - x0

    def gather(yi, xi):
        yc = jnp.clip(yi.astype(jnp.int32), 0, H - 1)
        xc = jnp.clip(xi.astype(jnp.int32), 0, W - 1)
        idx = yc * W + xc                                              # (B, P)
        flat = images.reshape(B, H * W, C)
        return jnp.take_along_axis(flat, idx[..., None], axis=1)

    out = (gather(y0, x0) * ((1 - wy) * (1 - wx))[..., None]
           + gather(y0, x0 + 1) * ((1 - wy) * wx)[..., None]
           + gather(y0 + 1, x0) * (wy * (1 - wx))[..., None]
           + gather(y0 + 1, x0 + 1) * (wy * wx)[..., None])
    return out.reshape(B, oh, ow, C)


def random_affine(key, images, out_hw=None, max_rot=15.0, scale=(0.7, 1.3),
                  max_shift=0.1):
    """Rotation + scale + shift ('rotations, scaling, distortion, resizing')
    in one bilinear resample."""
    B, H, W, _ = images.shape
    oh, ow = out_hw or (H, W)
    k1, k2, k3 = jax.random.split(key, 3)
    ang = jnp.deg2rad(jax.random.uniform(k1, (B,), minval=-max_rot,
                                         maxval=max_rot))
    sc = jax.random.uniform(k2, (B,), minval=scale[0], maxval=scale[1])
    shift = jax.random.uniform(k3, (B, 2), minval=-max_shift,
                               maxval=max_shift) * jnp.asarray([H, W])
    cos, sin = jnp.cos(ang) / sc, jnp.sin(ang) / sc
    cy, cx = (H - 1) / 2, (W - 1) / 2
    ocy, ocx = (oh - 1) / 2, (ow - 1) / 2
    # out (y,x) -> rotate/scale about center + shift
    m = jnp.stack([
        jnp.stack([cos, -sin, cy - cos * ocy + sin * ocx + shift[:, 0]], 1),
        jnp.stack([sin, cos, cx - sin * ocy - cos * ocx + shift[:, 1]], 1),
    ], 1)                                                              # (B,2,3)
    return _affine_resample(images, m, (oh, ow))


def augment(key, images, out_hw=(224, 224)):
    """The paper's full augmentation stack, fused order: geometric ->
    flip -> photometric -> noise."""
    k = jax.random.split(key, 5)
    x = random_affine(k[0], images, out_hw)
    x = random_flip(k[1], x)
    x = random_brightness(k[2], x)
    x = random_contrast(k[3], x)
    x = random_noise(k[4], x)
    return x
