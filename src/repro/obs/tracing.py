"""Nested span tracing with Chrome ``trace_event`` export.

The ROADMAP's top open item asks to measure *actual* comm/compute overlap
on a real backward pass instead of only auditing the bucketed schedule
statically from HLO (``launch/hlo_stats.bucket_audit``). Host-side spans
are the first half of that instrument: the trainer wraps each step's
phases (``data`` / ``dispatch`` / ``sync_wait`` / ``checkpoint``) in
``with tracer.span(...)``, giving a per-step wall-time breakdown that the
metrics JSONL records and :meth:`Tracer.export_chrome_trace` renders as a
Chrome/Perfetto-loadable ``trace_event`` file. The second half is the
device timeline: :func:`jax_profile` wraps the run in
``jax.profiler.trace`` so the XLA trace (where the per-bucket all-reduces
are visible overlapping backward compute) can be captured alongside.
docs/observability.md walks the full overlap-measurement recipe.

Spans are exception-safe (the record is closed and flagged ``error`` when
the body raises) and nest per-thread: depth/parent come from a
thread-local stack, timestamps from the monotonic clock relative to the
tracer's epoch -- wall-clock-free, like the sink stamps (repro.obs.sink).
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time


class Span:
    """One closed (or in-flight) span. ``duration`` is None until exit."""

    __slots__ = ("name", "t0", "duration", "depth", "parent", "tid", "step",
                 "args", "error")

    def __init__(self, name: str, t0: float, depth: int, parent: str | None,
                 tid: int, step: int | None, args: dict):
        self.name = name
        self.t0 = t0
        self.duration: float | None = None
        self.depth = depth
        self.parent = parent
        self.tid = tid
        self.step = step
        self.args = args
        self.error = False

    @property
    def t1(self) -> float:
        return self.t0 + (self.duration or 0.0)

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, t0={self.t0:.6f}, "
                f"dur={self.duration}, depth={self.depth})")


_NULL_SPAN = Span("null", 0.0, 0, None, 0, None, {})
_NULL_SPAN.duration = 0.0


class Tracer:
    """Collects closed spans; thread-safe, nesting tracked per thread."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._t0 = time.monotonic()
        self._lock = threading.Lock()
        self._closed: list[Span] = []
        self._local = threading.local()

    def _stack(self) -> list[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    @contextlib.contextmanager
    def span(self, name: str, step: int | None = None, **args):
        """``with tracer.span("sync/bucket3", step=7) as sp:`` -- on exit
        ``sp.duration`` holds the elapsed seconds. Yields a shared null
        span when the tracer is disabled (duration stays 0.0)."""
        if not self.enabled:
            yield _NULL_SPAN
            return
        stack = self._stack()
        sp = Span(name, time.monotonic() - self._t0, depth=len(stack),
                  parent=stack[-1].name if stack else None,
                  tid=threading.get_ident(), step=step, args=args)
        stack.append(sp)
        try:
            yield sp
        except BaseException:
            sp.error = True
            raise
        finally:
            sp.duration = time.monotonic() - self._t0 - sp.t0
            stack.pop()
            with self._lock:
                self._closed.append(sp)

    def spans(self, name: str | None = None,
              step: int | None = None) -> list[Span]:
        """Closed spans, optionally filtered, ordered by start time."""
        with self._lock:
            out = list(self._closed)
        if name is not None:
            out = [s for s in out if s.name == name]
        if step is not None:
            out = [s for s in out if s.step == step]
        out.sort(key=lambda s: s.t0)
        return out

    def phase_breakdown(self, step: int) -> dict[str, float]:
        """Total seconds per span name for one step (nested spans of the
        same step each contribute under their own name)."""
        out: dict[str, float] = {}
        for sp in self.spans(step=step):
            out[sp.name] = out.get(sp.name, 0.0) + (sp.duration or 0.0)
        return out

    def export_chrome_trace(self, path: str) -> int:
        """Write closed spans as Chrome ``trace_event`` JSON (complete
        "X" events, microsecond timestamps); load via chrome://tracing or
        https://ui.perfetto.dev. Returns the number of events written."""
        with self._lock:
            closed = sorted(self._closed, key=lambda s: (s.t0, s.depth))
        tids: dict[int, int] = {}
        events = []
        for sp in closed:
            tid = tids.setdefault(sp.tid, len(tids))
            args = {k: v for k, v in sp.args.items()}
            if sp.step is not None:
                args["step"] = sp.step
            if sp.error:
                args["error"] = True
            events.append({
                "name": sp.name, "cat": "host", "ph": "X",
                "ts": round(sp.t0 * 1e6, 3),
                "dur": round((sp.duration or 0.0) * 1e6, 3),
                "pid": 0, "tid": tid,
                "args": args,
            })
        doc = {"traceEvents": events, "displayTimeUnit": "ms"}
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f)
        os.replace(tmp, path)
        return len(events)


@contextlib.contextmanager
def jax_profile(log_dir: str | None):
    """Optionally wrap a block in ``jax.profiler.trace(log_dir)``.

    ``log_dir=None`` (the default everywhere) is a no-op; otherwise the
    XLA device trace (TensorBoard / Perfetto format) lands in ``log_dir``,
    which is how bucketed-overlap claims are checked against the *device*
    timeline rather than host wall time (docs/observability.md)."""
    if not log_dir:
        yield
        return
    import jax
    with jax.profiler.trace(log_dir):
        yield
