"""Thread-safe in-process metrics: counters, gauges, fixed-bucket histograms.

Recording must be cheap enough to sit on the training hot path (a lock
acquire + a float add / bisect), so instruments carry no labels, no
timestamps, and no per-observation allocation: a metric is one named slot
in a :class:`MetricsRegistry`, identified by a slash-separated path
(``"step/wall_s"``, ``"grad_sync/bucket03/nbytes"``,
``"events/elastic_recovery"``). The registry is the unit of sharing --
the trainer owns one per run and hands it to the checkpoint writer
(worker thread), the elastic supervisor, and the grad-sync layout
recorder, so a single lock-protected table accumulates the whole run.

``snapshot()`` renders everything to plain JSON-ready dicts; the trainer's
telemetry facade emits that as the final ``"kind": "summary"`` row of the
metrics JSONL (repro.obs.sink), which is what CI gates parse
(docs/observability.md has the metric-name table).

Call sites that must work without telemetry take a registry argument and
default it to :data:`NULL_REGISTRY`, whose instruments accept every call
and record nothing.
"""

from __future__ import annotations

import bisect
import math
import threading

#: Default histogram edges for durations in seconds: exponential from
#: 0.1 ms to ~420 s (2x steps). Upper-bound ("le") semantics; observations
#: above the last edge land in the +inf overflow bucket.
DEFAULT_TIME_EDGES_S = tuple(1e-4 * 2.0 ** i for i in range(22))

#: Default edges for byte sizes: 256 B to ~8 GiB (4x steps).
DEFAULT_BYTES_EDGES = tuple(256.0 * 4.0 ** i for i in range(13))


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        with self._lock:
            self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-written value (queue depth, loss scale, bucket bytes)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.RLock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram with upper-bound ("le") edge semantics.

    ``observe(v)`` increments the count of the first bucket whose edge is
    >= v (ties land in the bucket whose edge equals v); values above the
    last edge go to the +inf overflow bucket. Also tracks count/sum/min/max
    so means survive the snapshot.
    """

    __slots__ = ("name", "edges", "counts", "count", "sum", "min", "max",
                 "_lock")

    def __init__(self, name: str, edges, lock: threading.RLock):
        if not edges:
            raise ValueError(f"histogram {name}: needs at least one "
                             "bucket edge")
        self.name = name
        self.edges = tuple(sorted(float(e) for e in edges))
        self.counts = [0] * (len(self.edges) + 1)  # last = +inf overflow
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._lock = lock

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect.bisect_left(self.edges, v)
        with self._lock:
            self.counts[i] += 1
            self.count += 1
            self.sum += v
            if v < self.min:
                self.min = v
            if v > self.max:
                self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
            "buckets": [{"le": e, "count": c}
                        for e, c in zip(self.edges, self.counts)]
                       + [{"le": "inf", "count": self.counts[-1]}],
        }


class MetricsRegistry:
    """Create-or-get table of named instruments behind one RLock.

    The lock is shared with every instrument (recording and snapshotting
    never interleave mid-update), and re-entrant so an instrument method
    can be called while holding it.
    """

    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args, self._lock)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  edges=DEFAULT_TIME_EDGES_S) -> Histogram:
        """Create-or-get; ``edges`` only applies on first creation."""
        return self._get(name, Histogram, edges)

    def names(self, prefix: str = "") -> list[str]:
        with self._lock:
            return sorted(n for n in self._metrics if n.startswith(prefix))

    def remove_prefix(self, prefix: str) -> int:
        """Drop every instrument whose name starts with ``prefix``; returns
        how many were removed.

        For metric families that describe a *current configuration* rather
        than an accumulating series -- e.g. the ``grad_sync/bucketNN/*``
        schedule gauges -- a re-configuration (elastic downgrade, sync-path
        switch) can shrink the family, and the survivors of a plain
        re-publish would be stale. Publishers clear the family first so the
        exported set always matches the live schedule. An empty ``prefix``
        is rejected (clearing the whole registry is never what a publisher
        means).
        """
        if not prefix:
            raise ValueError("remove_prefix requires a non-empty prefix")
        with self._lock:
            doomed = [n for n in self._metrics if n.startswith(prefix)]
            for n in doomed:
                del self._metrics[n]
            return len(doomed)

    def snapshot(self) -> dict[str, dict]:
        """All instruments rendered to JSON-ready dicts, name-sorted."""
        with self._lock:
            return {n: self._metrics[n].snapshot()
                    for n in sorted(self._metrics)}


class _NullInstrument:
    """Accepts every recording call, stores nothing."""

    __slots__ = ()
    value = 0.0
    count = 0
    sum = 0.0
    mean = 0.0

    def inc(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


class NullRegistry(MetricsRegistry):
    """No-op registry for call sites running without telemetry."""

    _NULL = _NullInstrument()

    def __init__(self):
        super().__init__()

    def counter(self, name: str):
        return self._NULL

    def gauge(self, name: str):
        return self._NULL

    def histogram(self, name: str, edges=DEFAULT_TIME_EDGES_S):
        return self._NULL

    def names(self, prefix: str = "") -> list[str]:
        return []

    def snapshot(self) -> dict[str, dict]:
        return {}


#: Shared no-op registry: the default for every ``metrics=`` parameter.
NULL_REGISTRY = NullRegistry()
