"""Observability: structured metrics, JSONL sinks, span tracing.

The three building blocks (each usable standalone):

* :mod:`repro.obs.metrics` -- thread-safe counters / gauges / fixed-bucket
  histograms in a :class:`~repro.obs.metrics.MetricsRegistry`.
* :mod:`repro.obs.sink`    -- crash-tolerant JSONL artifacts (append +
  fsync-on-flush, size rotation, run-id + monotonic stamping).
* :mod:`repro.obs.tracing` -- nested host-side spans with Chrome
  ``trace_event`` export and an optional ``jax.profiler.trace`` hook.

:class:`Telemetry` bundles them for the trainer: one registry + tracer per
run, an optional sink when ``ObsConfig.metrics_path`` is set, and a
``close()`` that emits the final metrics snapshot as a ``"summary"`` row
and writes the Chrome trace. Construction is cheap and everything degrades
to near-zero overhead when disabled (null registry, null spans, no sink),
so the trainer always has a telemetry object and never branches on "is
observability on" in the hot path. Full schema + recipes:
docs/observability.md.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

from repro.obs.metrics import (DEFAULT_BYTES_EDGES, DEFAULT_TIME_EDGES_S,
                               MetricsRegistry, NULL_REGISTRY, NullRegistry)
from repro.obs.sink import JsonlSink, new_run_id, read_jsonl, read_run
from repro.obs.tracing import Span, Tracer, jax_profile

__all__ = [
    "DEFAULT_BYTES_EDGES", "DEFAULT_TIME_EDGES_S", "JsonlSink",
    "MetricsRegistry", "NULL_REGISTRY", "NullRegistry", "ObsConfig", "Span",
    "Telemetry", "Tracer", "fingerprint", "jax_profile", "new_run_id",
    "read_jsonl", "read_run",
]


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Trainer-facing observability knobs (``TrainerConfig.obs``)."""

    enabled: bool = True
    #: metrics/event JSONL path; None = in-memory registry only, no artifact
    metrics_path: str | None = None
    #: Chrome trace_event JSON written on close; None = no trace file
    trace_path: str | None = None
    #: jax.profiler.trace log dir wrapped around the run; None = off
    jax_profile_dir: str | None = None
    #: rotate the metrics JSONL when it exceeds this many bytes (0 = never)
    rotate_bytes: int = 0
    #: emit a per-step ``step_phases`` row every N steps (sink only)
    step_metrics_every: int = 1


def fingerprint(obj) -> str:
    """12-hex content hash of a JSON-serializable config summary.

    Deterministic across processes (canonical key order, ``default=str``
    for dtypes and other non-JSON leaves); used to join metrics artifacts
    to the resolved config that produced them (launch/dryrun.py)."""
    blob = json.dumps(obj, sort_keys=True, default=str).encode()
    return hashlib.sha256(blob).hexdigest()[:12]


class Telemetry:
    """One run's registry + tracer + (optional) sink, under one run_id."""

    def __init__(self, cfg: ObsConfig | None = None, *,
                 run_id: str | None = None, meta: dict | None = None):
        self.cfg = cfg = cfg or ObsConfig()
        on = cfg.enabled
        self.registry: MetricsRegistry = MetricsRegistry() if on \
            else NULL_REGISTRY
        self.tracer = Tracer(enabled=on)
        self.sink: JsonlSink | None = None
        if on and cfg.metrics_path:
            self.sink = JsonlSink(cfg.metrics_path, run_id=run_id,
                                  rotate_bytes=cfg.rotate_bytes, meta=meta)
        self.run_id = self.sink.run_id if self.sink else \
            (run_id or new_run_id())
        self._closed = False

    def span(self, name: str, step: int | None = None, **args):
        return self.tracer.span(name, step=step, **args)

    def emit(self, record: dict) -> None:
        """Mirror a record to the sink (no-op without one)."""
        if self.sink is not None:
            self.sink.emit(record)

    def event(self, etype: str, **kw) -> dict:
        """Count + emit an event row; returns the (unstamped) record."""
        self.registry.counter(f"events/{etype}").inc()
        rec = {"kind": "event", "event": etype, **kw}
        self.emit(rec)
        return rec

    def flush(self) -> None:
        if self.sink is not None:
            self.sink.flush()

    def summary(self) -> dict:
        """The final snapshot row (also what ``close`` emits)."""
        return {"kind": "summary", "run_id": self.run_id,
                "metrics": self.registry.snapshot()}

    def close(self) -> None:
        """Emit the summary row, export the Chrome trace, close the sink.
        Idempotent; safe to call on a run that crashed mid-step."""
        if self._closed:
            return
        self._closed = True
        if self.sink is not None:
            self.sink.emit(self.summary())
            self.sink.close()
        if self.cfg.enabled and self.cfg.trace_path:
            self.tracer.export_chrome_trace(self.cfg.trace_path)

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
