"""Crash-tolerant JSONL metric/event sinks.

Every train / dry-run / benchmark run should leave a machine-readable
artifact (the ROADMAP's overlap item needs real per-step timings, not
``print`` output that dies with the terminal). The contract:

* **One JSON object per line, one ``write`` call per record.** A crash can
  tear at most the final line; :func:`read_jsonl` drops a torn tail and
  returns every complete record -- the same "either the previous complete
  state or the new one" discipline the checkpoint layer uses
  (``repro.train.checkpoint``).
* **Append + fsync-on-flush.** Records are buffered-appended (cheap on the
  hot path); ``flush()`` is the durability barrier (fsync), which the
  trainer invokes at checkpoints and on close.
* **Size-based rotation.** When the current file exceeds ``rotate_bytes``
  it is renamed to ``<path>.1``, ``<path>.2``, ... (ascending = oldest
  first) and a fresh file opened; :func:`read_run` reads the whole chain
  in order.
* **Wall-clock-free stamping.** Each record carries the sink's ``run_id``,
  a monotonically increasing ``seq``, and ``t_s`` -- seconds on the
  monotonic clock since the sink was opened. No wall-clock timestamps:
  they lie across hosts and break replay/diff of otherwise deterministic
  runs. Join to real time (and to dry-run JSON artifacts) via ``run_id``.

Schema of a stamped record (docs/observability.md):

    {"run_id": "1f2e3d4c5b6a", "seq": 17, "t_s": 0.84213,
     "kind": "metric" | "event" | "summary" | "run_header", ...payload}
"""

from __future__ import annotations

import glob
import json
import os
import re
import threading
import time
import uuid


def new_run_id() -> str:
    """12-hex random run identifier (joins artifacts of one run)."""
    return uuid.uuid4().hex[:12]


class JsonlSink:
    """Append-only JSONL writer with rotation and explicit durability.

    Thread-safe: ``emit`` may be called from the training thread and the
    async checkpoint worker concurrently. Payload keys never override the
    stamp keys (``run_id``/``seq``/``t_s``).
    """

    def __init__(self, path: str, *, run_id: str | None = None,
                 rotate_bytes: int = 0, meta: dict | None = None,
                 fsync_on_flush: bool = True):
        self.path = path
        self.run_id = run_id or new_run_id()
        self.rotate_bytes = int(rotate_bytes)
        self._fsync = fsync_on_flush
        self._lock = threading.Lock()
        self._t0 = time.monotonic()
        self._seq = 0
        self._rotations = self._existing_rotations(path)
        self._closed = False
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        self._f = open(path, "ab")
        self._size = self._f.tell()
        self.emit({"kind": "run_header", "meta": meta or {}})

    @staticmethod
    def _existing_rotations(path: str) -> int:
        ns = [int(m.group(1)) for p in glob.glob(glob.escape(path) + ".*")
              if (m := re.fullmatch(re.escape(path) + r"\.(\d+)", p))]
        return max(ns, default=0)

    def emit(self, record: dict) -> None:
        """Stamp and append one record (one write call, no fsync)."""
        with self._lock:
            if self._closed:
                raise ValueError(f"sink {self.path} is closed")
            rec = {"run_id": self.run_id, "seq": self._seq,
                   "t_s": round(time.monotonic() - self._t0, 6)}
            rec.update((k, v) for k, v in record.items() if k not in rec)
            line = (json.dumps(rec, default=str) + "\n").encode()
            self._f.write(line)
            self._seq += 1
            self._size += len(line)
            if self.rotate_bytes and self._size >= self.rotate_bytes:
                self._rotate_locked()

    def _rotate_locked(self) -> None:
        self._f.flush()
        if self._fsync:
            os.fsync(self._f.fileno())
        self._f.close()
        self._rotations += 1
        os.replace(self.path, f"{self.path}.{self._rotations}")
        self._f = open(self.path, "ab")
        self._size = 0

    def flush(self) -> None:
        """Durability barrier: flush buffers and (by default) fsync."""
        with self._lock:
            if self._closed:
                return
            self._f.flush()
            if self._fsync:
                os.fsync(self._f.fileno())

    def close(self) -> None:
        """Flush + close. Idempotent; ``emit`` afterwards raises."""
        with self._lock:
            if self._closed:
                return
            self._f.flush()
            if self._fsync:
                os.fsync(self._f.fileno())
            self._f.close()
            self._closed = True

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_jsonl(path: str, strict: bool = False) -> list[dict]:
    """Parse one JSONL file, tolerating a crash-torn tail.

    A trailing line that fails to parse is silently dropped (the crash
    window of a torn final ``write``); a *non*-final bad line means real
    corruption and raises unless ``strict=False`` skips it.
    """
    records: list[dict] = []
    with open(path, "rb") as f:
        lines = f.read().split(b"\n")
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            records.append(json.loads(line))
        except json.JSONDecodeError:
            last = all(not more.strip() for more in lines[i + 1:])
            if last:
                break          # torn tail: drop, keep the complete prefix
            if strict:
                raise
    return records


def run_paths(path: str) -> list[str]:
    """The rotation chain for ``path``, oldest first, current file last."""
    ns = sorted(int(m.group(1))
                for p in glob.glob(glob.escape(path) + ".*")
                if (m := re.fullmatch(re.escape(path) + r"\.(\d+)", p)))
    chain = [f"{path}.{n}" for n in ns]
    if os.path.exists(path):
        chain.append(path)
    return chain


def read_run(path: str, strict: bool = False) -> list[dict]:
    """All records of a (possibly rotated) run, in emission order."""
    out: list[dict] = []
    for p in run_paths(path):
        out.extend(read_jsonl(p, strict=strict))
    return out
