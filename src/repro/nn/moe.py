"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

Used by granite-moe (40e top-8) and kimi-k2 (384e top-8). Design notes:

* Expert weights are stacked ``(E, d, f)`` and sharded over the ``model``
  mesh axis (expert parallelism): the per-expert einsum shards cleanly.
* Dispatch is sort-based with a fixed per-expert capacity
  ``C = ceil(T * k / E * capacity_factor)`` -- shape-static (jit-safe),
  drops overflow tokens (standard GShard/Switch semantics) and avoids the
  O(T*E*C) one-hot dispatch tensor that would dominate HBM.
* An auxiliary load-balance loss (Switch-style) is returned for training.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.nn import init as winit


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    act: str = "silu"
    router_dtype: object = jnp.float32


def moe_init(key, cfg: MoEConfig):
    k = jax.random.split(key, 4)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": {"kernel": winit.normal(k[0], (d, E), std=0.02)},
        "experts": {
            "up": winit.lecun_normal(k[1], (E, d, f), fan_in=d),
            "gate": winit.lecun_normal(k[2], (E, d, f), fan_in=d),
            "down": winit.lecun_normal(k[3], (E, f, d), fan_in=f),
        },
    }


_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def moe_apply(p, x, cfg: MoEConfig):
    """x: (B, S, d). Returns (y, aux_loss)."""
    B, S, d = x.shape
    T = B * S
    E, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(T * k / E * cfg.capacity_factor))
    xt = x.reshape(T, d)

    # --- route (fp32: router logits need dynamic range) ---
    logits = (xt.astype(cfg.router_dtype)
              @ p["router"]["kernel"].astype(cfg.router_dtype))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, topk_e = jax.lax.top_k(probs, k)                # (T, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)

    # --- load-balance aux loss (Switch) ---
    me = probs.mean(0)                                          # (E,)
    ce = jnp.zeros((E,), probs.dtype).at[topk_e.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(me * ce)

    # --- sort-based dispatch with fixed capacity ---
    flat_e = topk_e.reshape(T * k)                              # expert per slot
    tok_of_slot = jnp.repeat(jnp.arange(T), k)
    gate_of_slot = gate_vals.reshape(T * k)
    order = jnp.argsort(flat_e, stable=True)
    se, st_tok, s_gate = flat_e[order], tok_of_slot[order], gate_of_slot[order]
    # rank within the expert's contiguous group
    pos = jnp.arange(T * k) - jnp.searchsorted(se, se, side="left")
    keep = pos < cap
    slot_buf = jnp.full((E, cap), T, jnp.int32)                 # T = pad row
    gate_buf = jnp.zeros((E, cap), x.dtype)
    se_k = jnp.where(keep, se, E)                               # drop -> OOB
    slot_buf = slot_buf.at[se_k, pos].set(st_tok.astype(jnp.int32), mode="drop")
    gate_buf = gate_buf.at[se_k, pos].set(s_gate.astype(x.dtype), mode="drop")

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], 0)
    xe = xpad[slot_buf]                                         # (E, cap, d)

    # --- expert FFN (einsum shards over E on the model axis) ---
    w = p["experts"]
    act = _ACTS[cfg.act]
    h = jnp.einsum("ecd,edf->ecf", xe, w["up"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, w["gate"].astype(x.dtype))
    h = h * act(g)
    ye = jnp.einsum("ecf,efd->ecd", h, w["down"].astype(x.dtype))
    ye = ye * gate_buf[..., None]

    # --- combine ---
    y = jnp.zeros((T + 1, d), x.dtype)
    y = y.at[slot_buf.reshape(-1)].add(ye.reshape(-1, d))
    return y[:T].reshape(B, S, d), aux
