"""Attention: GQA/MQA/MHA, RoPE, qk-norm, logit softcap, sliding window,
cross-attention, and cached decode. Covers the attention variants of all
assigned architectures (gemma/gemma2/llama/qwen/musicgen/recurrentgemma
local-attn/llama-vision cross-attn).

Decode uses a KV cache; *local* (sliding-window) layers use a rolling
cache of ``window`` slots so a 500k-token context costs O(window) memory
per layer -- the mechanism that lets dense archs run the ``long_500k``
shape (DESIGN.md §4).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import init as winit
from repro.nn import layers as L


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    qk_norm: bool = False
    attn_softcap: float | None = None       # gemma2: 50.0
    window: int | None = None               # sliding-window size (local attn)
    query_scale: float | None = None        # default 1/sqrt(head_dim)
    cross_kv_dim: int | None = None         # cross-attn source dim (VLM)


# ------------------------------------------------------------------ RoPE --

def rope(x, positions, theta):
    """x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # positions: (B, S) -> (B, S, 1, half)
    ang = positions[..., None, None].astype(jnp.float32) * freq
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos.astype(x.dtype) - x2 * sin.astype(x.dtype)
    y2 = x2 * cos.astype(x.dtype) + x1 * sin.astype(x.dtype)
    return jnp.concatenate([y1, y2], axis=-1)


# ------------------------------------------------------------------ init --

def attn_init(key, cfg: AttnConfig):
    k = jax.random.split(key, 6)
    hd, h, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    kv_in = cfg.cross_kv_dim or cfg.d_model
    p = {
        "q": {"kernel": winit.lecun_normal(k[0], (cfg.d_model, h * hd))},
        "k": {"kernel": winit.lecun_normal(k[1], (kv_in, hkv * hd))},
        "v": {"kernel": winit.lecun_normal(k[2], (kv_in, hkv * hd))},
        "o": {"kernel": winit.lecun_normal(k[3], (h * hd, cfg.d_model))},
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(hd)
        p["k_norm"] = L.rmsnorm_init(hd)
    return p


def _project_qkv(p, x, kv_src, cfg: AttnConfig, positions, kv_positions,
                 use_rope=True):
    B = x.shape[0]
    q = (x @ p["q"]["kernel"].astype(x.dtype)).reshape(
        B, -1, cfg.n_heads, cfg.head_dim)
    kv = kv_src.astype(x.dtype)
    k = (kv @ p["k"]["kernel"].astype(x.dtype)).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    v = (kv @ p["v"]["kernel"].astype(x.dtype)).reshape(
        B, -1, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg: AttnConfig):
    """q: (B,Sq,H,D), k/v: (B,Skv,Hkv,D); GQA via head grouping."""
    scale = cfg.query_scale if cfg.query_scale is not None else cfg.head_dim ** -0.5
    groups = cfg.n_heads // cfg.n_kv_heads
    B, Sq, H, D = q.shape
    qg = q.reshape(B, Sq, cfg.n_kv_heads, groups, D)
    logits = jnp.einsum("bqkgd,bskd->bkgqs", qg * scale, k)
    if cfg.attn_softcap:
        c = cfg.attn_softcap
        logits = c * jnp.tanh(logits / c)
    logits = jnp.where(mask[:, None, None, :, :], logits.astype(jnp.float32),
                       -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(B, Sq, H * D)


def causal_mask(sq, skv, q_offset=0, window=None):
    """(sq, skv) bool mask; True = attend. q position i attends kv j iff
    j <= i+offset and (no window or j > i+offset-window)."""
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m


def _sdpa_q_chunked(q, k, v, cfg: AttnConfig, q_chunk: int,
                    unroll: bool = False):
    """Memory-bounded attention: scan over query chunks so the logits
    tensor is (B, H, q_chunk, S) instead of (B, H, S, S). The 32k prefill
    shapes are unloggable without this (flash-attention-style bounding; the
    softmax itself is still exact per chunk since the full key row fits).
    ``unroll`` replaces the lax.scan with a python loop -- used by the cost
    extrapolation because XLA cost_analysis excludes while-loop bodies."""
    B, S, H, D = q.shape
    nc = S // q_chunk
    qc = jnp.moveaxis(q.reshape(B, nc, q_chunk, H, D), 1, 0)

    def body(_, args):
        qi, idx = args
        offset = idx * q_chunk
        kj = jnp.arange(S)[None, :]
        qi_pos = jnp.arange(q_chunk)[:, None] + offset
        m = kj <= qi_pos
        if cfg.window is not None:
            m = m & (kj > qi_pos - cfg.window)
        out = _sdpa(qi, k, v, m[None], cfg)
        return None, out

    if unroll:
        outs = jnp.stack([body(None, (qc[i], jnp.asarray(i)))[1]
                          for i in range(nc)])
    else:
        _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, S, H * D)


# --------------------------------------------------------------- forward --

def self_attention(p, x, cfg: AttnConfig, positions=None, use_rope=True,
                   q_chunk: int = 1024, unroll: bool = False):
    """Full-sequence (training / prefill) self-attention. Sequences longer
    than 2*q_chunk use the query-chunked memory-bounded path."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, x, x, cfg, positions, positions, use_rope)
    if q_chunk and S > 2 * q_chunk and S % q_chunk == 0:
        out = _sdpa_q_chunked(q, k, v, cfg, q_chunk, unroll)
    else:
        mask = causal_mask(S, S, 0, cfg.window)[None]
        out = _sdpa(q, k, v, mask, cfg)
    return out @ p["o"]["kernel"].astype(x.dtype)


def cross_attention(p, x, kv_src, cfg: AttnConfig):
    """Cross-attention (VLM): queries from text stream, k/v from vision
    embeddings; no causal mask, no rope on kv."""
    B, S, _ = x.shape
    Skv = kv_src.shape[1]
    pos = jnp.zeros((B, S), jnp.int32)
    q, k, v = _project_qkv(p, x, kv_src, cfg, pos, pos[:, :Skv] if Skv <= S
                           else jnp.zeros((B, Skv), jnp.int32), use_rope=False)
    mask = jnp.ones((1, S, Skv), bool)
    out = _sdpa(q, k, v, mask, cfg)
    return out @ p["o"]["kernel"].astype(x.dtype)


# ---------------------------------------------------------------- decode --

def init_kv_cache(batch, cache_len, cfg: AttnConfig, dtype=jnp.bfloat16):
    """cache_len: full seq for global layers, ``window`` for local layers."""
    shape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_self_attention(p, x, cache, index, cfg: AttnConfig, use_rope=True):
    """One-token decode. x: (B, 1, d). ``index``: absolute position of the
    new token. Local layers use a rolling buffer: slot = index % cache_len.
    Returns (out, new_cache)."""
    B = x.shape[0]
    cache_len = cache["k"].shape[1]
    positions = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = _project_qkv(p, x, x, cfg, positions, positions, use_rope)
    slot = index % cache_len if cfg.window is not None else index
    k = lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, 1)
    v = lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, 1)
    kv_pos = jnp.arange(cache_len)[None, :]
    if cfg.window is not None:
        # rolling buffer: absolute position of slot s
        wrap = (index // cache_len) * cache_len
        abs_pos = jnp.where(kv_pos <= slot, wrap + kv_pos, wrap - cache_len + kv_pos)
        valid = (abs_pos <= index) & (abs_pos > index - min(cfg.window, cache_len)) & (abs_pos >= 0)
        mask = jnp.broadcast_to(valid, (B, cache_len))[:, None, :]
    else:
        valid = kv_pos <= index
        mask = jnp.broadcast_to(valid, (B, cache_len))[:, None, :]
    out = _sdpa(q, k, v, mask, cfg)
    out = out @ p["o"]["kernel"].astype(x.dtype)
    return out, {"k": k, "v": v}


def prefill_kv_cache(p, x, cfg: AttnConfig, cache_len, use_rope=True,
                     dtype=jnp.bfloat16):
    """Run projections over the prompt and build the cache (last
    ``cache_len`` positions for local layers)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    _, k, v = _project_qkv(p, x, x, cfg, positions, positions, use_rope)
    if cache_len >= S:
        pad = cache_len - S
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    else:
        # rolling buffer layout: slot = pos % cache_len
        start = S - cache_len
        k, v = k[:, start:], v[:, start:]
        shift = start % cache_len
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
    return {"k": k.astype(dtype), "v": v.astype(dtype)}
