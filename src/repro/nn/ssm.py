"""Mamba-2 SSD (state-space duality) block [arXiv:2405.21060].

Chunked SSD algorithm (the paper's Listing 1, adapted to JAX):
sequence is split into chunks of length Q; within a chunk the output is a
masked (1-semiseparable) attention-like product; across chunks a small
recurrence carries the (H, P, N) state. Training/prefill use the chunked
form (O(S Q) + O(S N P / Q)); decode is the pure recurrence
``h = exp(dt*A) h + dt * B x`` -- O(1) per token, which is what makes the
``long_500k`` decode shape linear for this arch.

Dimensions follow mamba2-2.7b: d_inner = 2 * d_model, head_dim P = 64,
H = d_inner / P heads, state N = 128, single B/C group (G=1 simplified,
multi-head B/C broadcast).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import init as winit
from repro.nn import layers as L


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256
    unroll_scan: bool = False   # python-loop the inter-chunk recurrence
    conv_width: int = 4
    dt_min: float = 1e-3
    dt_max: float = 0.1

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def ssd_init(key, cfg: SSDConfig):
    k = jax.random.split(key, 6)
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    # in_proj packs [z (gate), x, B, C, dt]
    zxbcdt = di * 2 + 2 * N + H
    dt = jnp.exp(jax.random.uniform(k[2], (H,)) *
                 (jnp.log(cfg.dt_max) - jnp.log(cfg.dt_min)) + jnp.log(cfg.dt_min))
    return {
        "in_proj": {"kernel": winit.lecun_normal(k[0], (cfg.d_model, zxbcdt))},
        "conv": {"kernel": winit.lecun_normal(
            k[1], (cfg.conv_width, di + 2 * N), fan_in=cfg.conv_width)},
        "dt_bias": jnp.log(jnp.expm1(dt)),                      # softplus^-1
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "out_norm": L.rmsnorm_init(di),
        "out_proj": {"kernel": winit.lecun_normal(k[4], (di, cfg.d_model))},
    }


def _split_proj(p, u, cfg: SSDConfig):
    di, N, H = cfg.d_inner, cfg.d_state, cfg.n_heads
    zxbcdt = u @ p["in_proj"]["kernel"].astype(u.dtype)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * N], axis=-1)
    return z, xbc, dt


def _conv1d(p, xbc, state=None):
    """Causal depthwise conv, width W. xbc: (B, S, C). state: (B, W-1, C)
    carried for decode. Returns (y, new_state)."""
    w = p["conv"]["kernel"].astype(xbc.dtype)                   # (W, C)
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                    # (B, S+W-1, C)
    y = sum(xp[:, i: i + xbc.shape[1]] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):]
    return jax.nn.silu(y), new_state


def _ssd_chunked(x, dt, A, B_, C, cfg: SSDConfig, h0=None):
    """x: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) (negative),
    B_/C: (B,S,N). Returns (y, h_final) with h: (B,H,P,N)."""
    Bb, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(cfg.chunk, S)
    pad = (-S) % Q
    if pad:
        # dt=0 padding is exact: decay=exp(0)=1 (state frozen), input dt*x=0
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S_pad = S + pad
    nc = S_pad // Q

    xa = (x * dt[..., None]).reshape(Bb, nc, Q, H, P)           # dt-weighted input
    a = (dt * A).reshape(Bb, nc, Q, H)                          # log decay per step
    Bc = B_.reshape(Bb, nc, Q, N)
    Cc = C.reshape(Bb, nc, Q, N)

    cum = jnp.cumsum(a, axis=2)                                 # (B,nc,Q,H)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for j <= i.
    # Mask BEFORE exp: above-diagonal seg is positive and exp would inf,
    # poisoning the backward pass through where (inf * 0 = NaN in vjp).
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]         # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -60.0)
    Lmat = jnp.exp(seg)
    qk = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)                  # (B,nc,Q,Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp",
                         qk.astype(jnp.float32), Lmat, xa.astype(jnp.float32))

    # chunk summaries: state contribution of each chunk
    dec_to_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjn,bcjh,bcjhp->bchpn",
                             Bc.astype(jnp.float32), dec_to_end, xa.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # (B,nc,H)

    # inter-chunk recurrence over nc chunks (sequential scan, nc is small)
    h_init = (jnp.zeros((Bb, H, P, N), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    if nc == 1:
        # no scan: keeps the math visible to XLA cost analysis
        h_prev = h_init[:, None]
        h_last = h_init * chunk_decay[:, 0, :, None, None] + chunk_state[:, 0]
    elif cfg.unroll_scan:
        hs, h = [], h_init
        for c in range(nc):
            hs.append(h)
            h = h * chunk_decay[:, c, :, None, None] + chunk_state[:, c]
        h_last, h_prev = h, jnp.stack(hs, axis=1)
    else:
        def step(h, inp):
            cs, cd = inp
            h_new = h * cd[..., None, None] + cs                # (B,H,P,N)
            return h_new, h
        cs_t = jnp.moveaxis(chunk_state, 1, 0)
        cd_t = jnp.moveaxis(chunk_decay, 1, 0)
        h_last, h_prev = lax.scan(step, h_init, (cs_t, cd_t))
        h_prev = jnp.moveaxis(h_prev, 0, 1)                     # (B,nc,H,P,N)

    dec_from_start = jnp.exp(cum)                               # (B,nc,Q,H)
    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cc.astype(jnp.float32), dec_from_start, h_prev)
    y = (y_intra + y_inter).reshape(Bb, S_pad, H, P)[:, :S]
    return y.astype(x.dtype), h_last


def ssd_apply(p, u, cfg: SSDConfig, state=None, return_state=False):
    """Full-sequence SSD block. u: (B, S, d_model)."""
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _conv1d(p, xbc, conv_state)
    x, B_, C = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(*x.shape[:2], H, P)
    h0 = None if state is None else state["ssm"]
    y, h = _ssd_chunked(xh, dt, A, B_, C, cfg, h0)
    y = y + p["D"].astype(y.dtype)[:, None] * xh                # skip
    y = y.reshape(*u.shape[:2], di)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]["kernel"].astype(u.dtype)
    if return_state:
        return out, {"ssm": h, "conv": new_conv}
    return out


def ssd_init_state(batch, cfg: SSDConfig, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.d_inner + 2 * cfg.d_state), dtype),
    }


def ssd_decode_step(p, u, state, cfg: SSDConfig):
    """One-token recurrence. u: (B, 1, d_model). O(1) in context length."""
    di, N, H, P = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc, new_conv = _conv1d(p, xbc, state["conv"])
    x, B_, C = jnp.split(xbc, [di, di + N], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]   # (B,H)
    A = -jnp.exp(p["A_log"])
    xh = x[:, 0].reshape(-1, H, P)                              # (B,H,P)
    decay = jnp.exp(dt * A)                                     # (B,H)
    h = state["ssm"] * decay[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", B_[:, 0].astype(jnp.float32), dt, xh.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", C[:, 0].astype(jnp.float32), h)
    y = y.astype(u.dtype) + p["D"].astype(u.dtype)[:, None] * xh
    y = y.reshape(-1, 1, di)
    y = L.rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    out = y @ p["out_proj"]["kernel"].astype(u.dtype)
    return out, {"ssm": h, "conv": new_conv}
