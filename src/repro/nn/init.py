"""Parameter initializers (paper §3.2: 'All layers in the model are
initialized by the values described in [10]' -- He-style fan-in normal for
convs, zeros for the last BN gamma of each residual block)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def he_normal(key, shape, fan_in=None, dtype=jnp.float32):
    if fan_in is None:
        fan_in = int(np.prod(shape[:-1]))
    std = float(np.sqrt(2.0 / max(fan_in, 1)))
    return std * jax.random.normal(key, shape, dtype)


def lecun_normal(key, shape, fan_in=None, dtype=jnp.float32):
    if fan_in is None:
        fan_in = int(np.prod(shape[:-1]))
    std = float(np.sqrt(1.0 / max(fan_in, 1)))
    return std * jax.random.normal(key, shape, dtype)


def normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


def zeros(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)
