"""Functional NN layers: param-pytree based (no flax; MaxText-style).

Every layer is a pair of module-level functions
    <layer>_init(key, ...) -> params
    <layer>(params, x, ...) -> y
Parameters are stored fp32 (master copy); ``cast`` at apply time implements
the mixed-precision policy (paper §3.2: fwd/bwd in half precision, BN and
LARS statistics in fp32).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import init as winit


def cast(params, dtype):
    """Compute-dtype view of the fp32 master params."""
    return jax.tree.map(lambda p: p.astype(dtype) if p.dtype == jnp.float32 else p,
                        params)


# ------------------------------------------------------------------ dense --

def dense_init(key, in_dim, out_dim, use_bias=True, initializer=winit.he_normal):
    kk, _ = jax.random.split(key)
    p = {"kernel": initializer(kk, (in_dim, out_dim), fan_in=in_dim)}
    if use_bias:
        p["bias"] = jnp.zeros((out_dim,), jnp.float32)
    return p


def dense(p, x):
    y = x @ p["kernel"].astype(x.dtype)
    if "bias" in p:
        y = y + p["bias"].astype(y.dtype)
    return y


# ------------------------------------------------------------------- conv --

def conv_init(key, kh, kw, cin, cout):
    return {"kernel": winit.he_normal(key, (kh, kw, cin, cout),
                                      fan_in=kh * kw * cin)}


def conv(p, x, stride=1, padding="SAME"):
    """NHWC conv."""
    s = (stride, stride) if isinstance(stride, int) else stride
    return lax.conv_general_dilated(
        x, p["kernel"].astype(x.dtype), window_strides=s, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


# ------------------------------------------------------------- batch norm --

def batchnorm_init(dim, zero_gamma=False):
    return {
        "bn_scale": jnp.zeros((dim,), jnp.float32) if zero_gamma
        else jnp.ones((dim,), jnp.float32),
        "bn_bias": jnp.zeros((dim,), jnp.float32),
    }


def batchnorm(p, x, *, stats=None, dp_axes=(), eps=1e-5, return_stats=False):
    """BN "without moving average" (paper §3.2 / Akiba et al. [5]).

    Train: statistics are the *synchronized batch* mean/variance -- reduced
    across the data-parallel axes in FP32 ("communication to synchronize
    batch mean and batch squared mean was conducted in FP32"). No EMA is
    kept; evaluation uses ``stats`` computed by a calibration pass.
    """
    axes = tuple(range(x.ndim - 1))
    if stats is not None:                      # eval path
        mean, var = stats
    else:
        xf = x.astype(jnp.float32)
        mean = xf.mean(axes)
        sq = (xf * xf).mean(axes)
        if dp_axes:
            # fp32 cross-replica sync of mean and squared mean
            mean = lax.pmean(mean, dp_axes)
            sq = lax.pmean(sq, dp_axes)
        var = sq - mean * mean
    inv = lax.rsqrt(var + eps) * p["bn_scale"]
    y = (x.astype(jnp.float32) - mean) * inv + p["bn_bias"]
    y = y.astype(x.dtype)
    if return_stats:
        return y, (mean, var)
    return y


# ------------------------------------------------------- layer/rms norms --

def layernorm_init(dim):
    return {"norm_scale": jnp.ones((dim,), jnp.float32),
            "norm_bias": jnp.zeros((dim,), jnp.float32)}


def layernorm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps) * p["norm_scale"] + p["norm_bias"]
    return y.astype(x.dtype)


def rmsnorm_init(dim):
    return {"norm_scale": jnp.zeros((dim,), jnp.float32)}


def rmsnorm(p, x, eps=1e-6):
    """Gemma-style: scale stored as (1 + w), zero-init."""
    xf = x.astype(jnp.float32)
    y = xf * lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    return (y * (1.0 + p["norm_scale"])).astype(x.dtype)


# -------------------------------------------------------------- embedding --

def embedding_init(key, vocab, dim):
    return {"embedding": winit.normal(key, (vocab, dim), std=0.02)}


def embed(p, ids, dtype=jnp.bfloat16):
    return p["embedding"].astype(dtype)[ids]


def unembed(p, x):
    return x @ p["embedding"].astype(x.dtype).T


# ---------------------------------------------------------------- pooling --

def max_pool(x, window=3, stride=2, padding="SAME"):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, window, window, 1), (1, stride, stride, 1),
        padding)


def global_avg_pool(x):
    return x.mean(axis=(1, 2))


# ----------------------------------------------------------------- MLPs ---

def mlp_init(key, d_model, d_ff, gated=True, act="gelu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"up": dense_init(k1, d_model, d_ff, use_bias=False,
                          initializer=winit.lecun_normal),
         "down": dense_init(k2, d_ff, d_model, use_bias=False,
                            initializer=winit.lecun_normal)}
    if gated:
        p["gate"] = dense_init(k3, d_model, d_ff, use_bias=False,
                               initializer=winit.lecun_normal)
    return p


_ACTS = {"gelu": jax.nn.gelu, "silu": jax.nn.silu, "relu": jax.nn.relu,
         "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}


def mlp(p, x, act="gelu"):
    h = dense(p["up"], x)
    if "gate" in p:
        h = h * _ACTS[act](dense(p["gate"], x))
    else:
        h = _ACTS[act](h)
    return dense(p["down"], h)
