"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block = linear-in -> temporal conv1d (width 4) -> RG-LRU -> gated linear-out.
The RG-LRU recurrence per channel:

    r_t = sigmoid(W_a x_t + b_a)            (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)            (input gate)
    a_t = a^(c * r_t)         with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill run the recurrence with ``lax.associative_scan``
(log-depth); decode is the O(1) per-token update. State per layer:
(B, d_rnn) hidden + (B, W-1, d_rnn) conv tail.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from repro.nn import init as winit


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_model: int
    d_rnn: int | None = None          # default d_model
    conv_width: int = 4
    c: float = 8.0

    @property
    def width(self) -> int:
        return self.d_rnn or self.d_model


def rglru_init(key, cfg: RGLRUConfig):
    k = jax.random.split(key, 7)
    d, w = cfg.d_model, cfg.width
    # Lambda init so that a = sigmoid(Lambda) in [0.9, 0.999]
    u = jax.random.uniform(k[3], (w,), minval=0.9, maxval=0.999)
    lam = jnp.log(u / (1 - u))
    return {
        "in_x": {"kernel": winit.lecun_normal(k[0], (d, w))},
        "in_gate": {"kernel": winit.lecun_normal(k[1], (d, w))},
        "conv": {"kernel": winit.lecun_normal(k[2], (cfg.conv_width, w),
                                              fan_in=cfg.conv_width)},
        "rg_kernel": winit.normal(k[4], (w, w), std=w ** -0.5),
        "rg_bias": jnp.zeros((w,), jnp.float32),
        "ig_kernel": winit.normal(k[5], (w, w), std=w ** -0.5),
        "ig_bias": jnp.zeros((w,), jnp.float32),
        "lambda_param": lam,
        "out": {"kernel": winit.lecun_normal(k[6], (w, d))},
    }


def _conv1d(p, x, state=None):
    w = p["conv"]["kernel"].astype(x.dtype)
    W = w.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
           if state is None else state.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i: i + x.shape[1]] * w[i] for i in range(W))
    return y, xp[:, -(W - 1):]


def _gates(p, x, cfg: RGLRUConfig):
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["rg_kernel"] + p["rg_bias"])
    i = jax.nn.sigmoid(xf @ p["ig_kernel"] + p["ig_bias"])
    log_a = cfg.c * r * jax.nn.log_sigmoid(p["lambda_param"])   # a = sigmoid(L)^(c*r)
    a = jnp.exp(log_a)
    gated_x = i * xf
    beta = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12))
    return a, beta * gated_x


def rglru_apply(p, u, cfg: RGLRUConfig, state=None, return_state=False):
    """u: (B, S, d_model)."""
    x = u @ p["in_x"]["kernel"].astype(u.dtype)
    gate = jax.nn.gelu(u @ p["in_gate"]["kernel"].astype(u.dtype))
    conv_state = None if state is None else state["conv"]
    x, new_conv = _conv1d(p, x, conv_state)
    a, bx = _gates(p, x, cfg)                                   # (B,S,w) fp32

    # h_t = a_t h_{t-1} + bx_t  via associative scan on (a, bx)
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    if state is not None:
        # prepend carried hidden as step 0
        h0 = state["hidden"].astype(jnp.float32)[:, None]
        a = jnp.concatenate([jnp.ones_like(h0), a], axis=1)
        bx = jnp.concatenate([h0, bx], axis=1)
        _, h = lax.associative_scan(combine, (a, bx), axis=1)
        h = h[:, 1:]
    else:
        _, h = lax.associative_scan(combine, (a, bx), axis=1)

    y = (h.astype(u.dtype) * gate) @ p["out"]["kernel"].astype(u.dtype)
    if return_state:
        return y, {"hidden": h[:, -1], "conv": new_conv}
    return y


def rglru_init_state(batch, cfg: RGLRUConfig, dtype=jnp.bfloat16):
    return {"hidden": jnp.zeros((batch, cfg.width), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.width), dtype)}


def rglru_decode_step(p, u, state, cfg: RGLRUConfig):
    """u: (B, 1, d_model) -> (y, new_state). O(1) per token."""
    x = u @ p["in_x"]["kernel"].astype(u.dtype)
    gate = jax.nn.gelu(u @ p["in_gate"]["kernel"].astype(u.dtype))
    x, new_conv = _conv1d(p, x, state["conv"])
    a, bx = _gates(p, x, cfg)
    h = a[:, 0] * state["hidden"].astype(jnp.float32) + bx[:, 0]
    y = (h[:, None].astype(u.dtype) * gate) @ p["out"]["kernel"].astype(u.dtype)
    return y, {"hidden": h, "conv": new_conv}
