"""Small cross-layer utilities (no jax dependency at import time)."""
