"""One shared retry loop: jittered exponential backoff with a deadline cap.

Before this module the repo grew three ad-hoc copies of the same loop
(``checkpoint.save``, ``Trainer._fetch_batch``, and the elastic recovery
supervisor would have been the fourth). They drifted: none of them jittered
(synchronized retries from thousands of workers hammer a recovering
filesystem in lockstep) and none of them bounded *total* time, only attempt
count. ``retry_call`` is the single implementation; callers keep their own
error types by catching the re-raised final exception.

Semantics:

* attempt 0 runs immediately; up to ``retries`` further attempts follow,
  sleeping ``backoff_s * 2**k`` (capped at ``max_backoff_s``) plus a
  deterministic jitter of up to ``jitter`` of the delay (seeded ``Random``,
  so tests and distributed replays are reproducible);
* only exceptions in ``retry_on`` are retried -- anything else propagates
  immediately;
* ``deadline_s`` caps the *total* elapsed time including the upcoming
  sleep: if the next sleep would cross the deadline, the last exception is
  re-raised now instead of burning wall-clock on a retry that cannot help
  (a trainer stuck retrying is indistinguishable from a hung trainer to
  the supervisor above it);
* ``on_retry(attempt, exc)`` observes every failed attempt that will be
  retried (the trainer turns these into history events).
"""

from __future__ import annotations

import random
import time
from typing import Callable, Iterable


def retry_call(fn: Callable, *, retries: int = 3, backoff_s: float = 0.05,
               max_backoff_s: float = 2.0, jitter: float = 0.25,
               deadline_s: float | None = None,
               retry_on: tuple | Iterable = (OSError,),
               on_retry: Callable[[int, BaseException], None] | None = None,
               seed: int = 0, sleep: Callable[[float], None] = time.sleep,
               clock: Callable[[], float] = time.monotonic):
    """Call ``fn()`` with retries; returns its result or re-raises the last
    exception after the attempt budget or the deadline is exhausted."""
    retry_on = tuple(retry_on)
    rng = random.Random(seed)
    start = clock()
    last: BaseException | None = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except retry_on as e:
            last = e
            if attempt >= retries:
                break
            delay = min(backoff_s * (2.0 ** attempt), max_backoff_s)
            delay *= 1.0 + jitter * rng.random()
            if deadline_s is not None and \
                    clock() - start + delay > deadline_s:
                break
            if on_retry is not None:
                on_retry(attempt, e)
            sleep(delay)
    assert last is not None
    raise last
