"""llama-3.2-vision-90b [vlm] -- decoder with interleaved cross-attention.

[hf:meta-llama/Llama-3.2-11B-Vision, scaled to 90B] 100 layers total: every
5th layer is a cross-attention layer over vision embeddings (80 self + 20
cross), d_model 8192, 64 heads GQA kv=8 (head_dim 128), SwiGLU d_ff 28672,
vocab 128256, rope theta 500k. The ViT+projector is a STUB per the
assignment carve-out: ``input_specs`` provides precomputed patch embeddings
(B, 1601, 7680) consumed by the cross-attention k/v projections.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b", arch_type="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=28672, vocab=128_256,
        pattern=("attn", "attn", "attn", "attn", "cross"),
        act="silu", norm="rmsnorm", rope_theta=500_000.0,
        tie_embeddings=False, cross_kv_dim=7680, vision_tokens=1601,
        source="hf:meta-llama/Llama-3.2-11B-Vision")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama-3.2-vision-90b-smoke", arch_type="vlm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=128, pattern=("attn", "cross"),
        act="silu", norm="rmsnorm", tie_embeddings=False,
        cross_kv_dim=96, vision_tokens=16)
