"""Per-mesh communication hardware models and bucket-size defaults.

The bucket autotuner (``repro.core.autotune``) needs three constants per
fabric -- link bandwidth, per-step latency, and the backward-pass wall
time it overlaps with. This module is the single place those constants
live for the production meshes (``launch.mesh.make_production_mesh``), so
``launch.dryrun`` and the trainer resolve ``bucket_bytes="auto"`` against
the same numbers the roofline and ``benchmarks/allreduce.py`` use.

The per-arch default is simply ``"auto"`` for every arch that runs the
manual grad sync: the point of the autotuner is that no arch should carry
a hand-set byte count. FSDP archs (``launch.dryrun.FSDP_ARCHS``) get ``0``
-- XLA derives their collective schedule from shardings and
``bucket_bytes`` never reaches a sync.
"""

from __future__ import annotations

from repro.core.autotune import HardwareModel

#: Fabric constants per production mesh (paper-target numbers; see
#: docs/gradient_sync.md "Autotuning bucket_bytes"). The 2-pod mesh pays
#: the slower inter-pod links on its vertical phase, modeled here as a
#: lower effective bandwidth and a higher per-step latency.
HW_BY_MESH: dict[str, HardwareModel] = {
    "pod16x16": HardwareModel(link_bw=50e9, latency_s=1e-6,
                              backward_seconds=0.040, name="pod16x16"),
    "pod2x16x16": HardwareModel(link_bw=25e9, latency_s=5e-6,
                                backward_seconds=0.040, name="pod2x16x16"),
}


def hw_for_mesh(mesh, backward_seconds: float | None = None) -> HardwareModel:
    """HardwareModel for a mesh (object or name); unknown meshes fall back
    to the single-pod constants. ``backward_seconds`` overrides the default
    overlap window when the caller has a per-arch estimate."""
    name = mesh if isinstance(mesh, str) else (
        "pod2x16x16" if "pod" in getattr(mesh, "axis_names", ()) else
        "pod16x16")
    hw = HW_BY_MESH.get(name, HW_BY_MESH["pod16x16"])
    if backward_seconds is not None:
        import dataclasses
        hw = dataclasses.replace(hw, backward_seconds=backward_seconds)
    return hw


def backward_seconds_estimate(step_flops: float, n_chips: int,
                              peak_flops_per_chip: float = 90e12,
                              mfu: float = 0.4) -> float:
    """Rough backward wall time from a compiled step's FLOPs.

    Backward is ~2/3 of a train step's FLOPs (fwd + 2x in bwd); divide by
    the fleet's realizable throughput (peak x an assumed MFU). Only the
    *scale* matters -- ``backward_seconds`` moves where overlap saturates
    in the cost model, not the latency/bandwidth knee -- so a 2x error
    here barely moves the picked bucket size.
    """
    if step_flops <= 0 or n_chips <= 0:
        return HW_BY_MESH["pod16x16"].backward_seconds
    return (2.0 / 3.0) * step_flops / (n_chips * peak_flops_per_chip * mfu)


def default_bucket_bytes(arch_id: str, fsdp: bool = False) -> int | str:
    """Per-arch ``GradSyncConfig.bucket_bytes`` default: ``"auto"`` for
    every manually-synced arch, ``0`` for FSDP archs (no manual sync)."""
    return 0 if fsdp else "auto"
