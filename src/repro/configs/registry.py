"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

from repro.models.transformer import ArchConfig

ARCH_IDS = (
    "musicgen-medium",
    "recurrentgemma-9b",
    "llama-3.2-vision-90b",
    "gemma-7b",
    "granite-moe-3b-a800m",
    "kimi-k2-1t-a32b",
    "llama3-405b",
    "qwen3-1.7b",
    "mamba2-2.7b",
    "gemma2-27b",
)

_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
    "gemma-7b": "gemma_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "llama3-405b": "llama3_405b",
    "qwen3-1.7b": "qwen3_1_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "gemma2-27b": "gemma2_27b",
}


def _module(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")


def get(arch_id: str) -> ArchConfig:
    return _module(arch_id).arch()


def get_smoke(arch_id: str) -> ArchConfig:
    return _module(arch_id).smoke()
