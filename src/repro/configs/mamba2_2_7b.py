"""mamba2-2.7b [ssm] -- SSD (state-space duality), attention-free.

[arXiv:2405.21060] Mamba-2 2.7B: 64 SSD layers, d_model 2560
(d_inner 5120, head_dim 64 -> 80 heads), state N=128, no attention, no
separate MLP (the SSD block is the whole layer), vocab 50280. long_500k
decode is O(1)-state per token -- runs natively.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b", arch_type="ssm",
        n_layers=64, d_model=2560, n_heads=80, n_kv_heads=1, head_dim=64,
        d_ff=0, vocab=50_280, pattern=("ssd",), mlp="none",
        ssm_state=128, ssm_head_dim=64, norm="rmsnorm",
        source="arXiv:2405.21060")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="mamba2-2.7b-smoke", arch_type="ssm",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=0, vocab=128, pattern=("ssd",), mlp="none",
        ssm_state=16, ssm_head_dim=32, norm="rmsnorm")
