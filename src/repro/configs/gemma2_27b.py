"""gemma2-27b [dense] -- local+global alternating attention, logit softcap.

[arXiv:2408.00118] Gemma 2 27B: 46 layers alternating (local window 4096,
global), d_model 4608, 32 heads GQA kv=16 (head_dim 128), GeGLU d_ff 36864,
vocab 256000, attention softcap 50, final-logit softcap 30, post-block
RMSNorms, embedding scaling, tied embeddings. For long_500k the
long-context variant turns global layers into window-4096 local layers
(DESIGN.md §4).
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b", arch_type="dense",
        n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
        d_ff=36864, vocab=256_000, pattern=("local", "attn"),
        act="gelu", norm="rmsnorm", post_norm=True, window=4096,
        logit_softcap=30.0, attn_softcap=50.0, embed_scale=True,
        source="arXiv:2408.00118")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma2-27b-smoke", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=128, pattern=("local", "attn"),
        act="gelu", norm="rmsnorm", post_norm=True, window=16,
        logit_softcap=30.0, attn_softcap=50.0, embed_scale=True)
