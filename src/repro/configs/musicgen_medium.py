"""musicgen-medium [audio] -- decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284] MusicGen (Copet et al., 2023), medium: 48 layers,
d_model 1536, 24 heads (MHA, kv=24), d_ff 6144, vocab 2048 (EnCodec
codebook). The conv audio codec is a STUB per the assignment carve-out:
``input_specs`` provides token ids (the 4 codebooks flattened by the delay
pattern into one stream). LayerNorm + plain GELU FFN like the original;
RoPE replaces MusicGen's sinusoidal embedding (TPU-idiomatic; documented
deviation).
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium", arch_type="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
        d_ff=6144, vocab=2048, pattern=("attn",),
        act="gelu", gated_mlp=False, norm="layernorm",
        tie_embeddings=False, source="arXiv:2306.05284")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="musicgen-medium-smoke", arch_type="audio",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab=128, pattern=("attn",),
        act="gelu", gated_mlp=False, norm="layernorm", tie_embeddings=False)
