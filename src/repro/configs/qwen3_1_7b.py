"""qwen3-1.7b [dense] -- qk-norm, GQA.

[hf:Qwen/Qwen3-1.7B family per assignment] 28 layers, d_model 2048,
16 heads GQA kv=8 (head_dim 128), SwiGLU d_ff 6144, vocab 151936,
RMSNorm on q/k per head (qk_norm), tied embeddings, rope theta 1M.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b", arch_type="dense",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
        d_ff=6144, vocab=151_936, pattern=("attn",),
        act="silu", norm="rmsnorm", qk_norm=True, rope_theta=1_000_000.0,
        source="hf:Qwen/Qwen3-8B")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="qwen3-1.7b-smoke", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab=128, pattern=("attn",),
        act="silu", norm="rmsnorm", qk_norm=True)
