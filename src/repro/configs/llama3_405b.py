"""llama3-405b [dense] -- the largest dense assigned arch.

[arXiv:2407.21783] Llama 3.1 405B: 126 layers, d_model 16384, 128 heads
GQA kv=8 (head_dim 128), SwiGLU d_ff 53248, vocab 128256, rope theta 500k.
Needs fsdp param sharding + remat for the train_4k shape.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b", arch_type="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
        d_ff=53248, vocab=128_256, pattern=("attn",),
        act="silu", norm="rmsnorm", rope_theta=500_000.0,
        tie_embeddings=False, source="arXiv:2407.21783")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="llama3-405b-smoke", arch_type="dense",
        n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, head_dim=32,
        d_ff=512, vocab=256, pattern=("attn",),
        act="silu", norm="rmsnorm", tie_embeddings=False)
