"""kimi-k2-1t-a32b [moe] -- trillion-parameter MoE, 384 experts top-8.

[arXiv:2501.kimi2 paper-table] 61 layers (first layer dense FFN, 60 MoE),
d_model 7168, 64 heads GQA kv=8 (head_dim 128; the real K2 uses MLA --
adapted to GQA per the assignment spec), experts d_ff 2048, 384 experts
top-8 (~32B active), vocab 163840. Requires fsdp-style param sharding to
fit any single pod (see launch/mesh.py sharding rules).
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b", arch_type="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=128,
        d_ff=2048, vocab=163_840, pattern=("attn",),
        mlp="moe", n_experts=384, top_k=8, first_dense=1,
        act="silu", norm="rmsnorm", tie_embeddings=False,
        rope_theta=50_000.0, source="arXiv:2501.kimi2")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="kimi-k2-1t-a32b-smoke", arch_type="moe",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=128, pattern=("attn",),
        mlp="moe", n_experts=4, top_k=2, first_dense=1,
        act="silu", norm="rmsnorm", tie_embeddings=False)
