"""gemma-7b [dense] -- GeGLU, wide head_dim 256, MHA.

[arXiv:2403.08295] Gemma 7B: 28 layers, d_model 3072, 16 heads kv=16
(head_dim 256; the 2B variant uses MQA), GeGLU d_ff 24576, vocab 256000,
embeddings scaled by sqrt(d_model), tied unembedding.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b", arch_type="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, head_dim=256,
        d_ff=24576, vocab=256_000, pattern=("attn",),
        act="gelu", norm="rmsnorm", embed_scale=True,
        source="arXiv:2403.08295")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="gemma-7b-smoke", arch_type="dense",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=4, head_dim=64,
        d_ff=512, vocab=128, pattern=("attn",),
        act="gelu", norm="rmsnorm", embed_scale=True)
