"""granite-moe-3b-a800m [moe] -- 40 experts, top-8.

[hf:ibm-granite/granite-3.0-3b-a800m-base family] 32 layers, d_model 1536,
24 heads GQA kv=8 (head_dim 64), MoE with 40 experts of d_ff 512, top-8
routing, SwiGLU experts, vocab 49155, tied embeddings.
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m", arch_type="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
        d_ff=512, vocab=49_155, pattern=("attn",),
        mlp="moe", n_experts=40, top_k=8,
        act="silu", norm="rmsnorm",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="granite-moe-3b-a800m-smoke", arch_type="moe",
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=64, vocab=128, pattern=("attn",),
        mlp="moe", n_experts=4, top_k=2, act="silu", norm="rmsnorm")
