"""recurrentgemma-9b [hybrid] -- RG-LRU + local attention, 1 attn : 2 rec.

[arXiv:2402.19427] Griffin/RecurrentGemma: 38 layers in (rglru, rglru,
local-attn) repeating pattern (2 leading rglru layers form the unscanned
prefix, 12 scanned pattern blocks), d_model 4096, 16 heads with MQA
(kv=1, head_dim 256), GeGLU d_ff 12288, vocab 256000, local window 2048,
Gemma-style embedding scaling. long_500k runs natively (linear state).
"""

from repro.models.transformer import ArchConfig


def arch() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b", arch_type="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
        d_ff=12288, vocab=256_000, pattern=("rglru", "rglru", "local"),
        act="gelu", norm="rmsnorm", window=2048, embed_scale=True,
        source="arXiv:2402.19427")


def smoke() -> ArchConfig:
    return ArchConfig(
        name="recurrentgemma-9b-smoke", arch_type="hybrid",
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab=128, pattern=("rglru", "rglru", "local"),
        act="gelu", norm="rmsnorm", window=16, embed_scale=True)
