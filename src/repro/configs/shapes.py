"""Assigned input shapes and the step kind each one lowers.

  train_4k     seq 4,096    global_batch 256   -> train_step
  prefill_32k  seq 32,768   global_batch 32    -> prefill_step
  decode_32k   seq 32,768   global_batch 128   -> serve_step (1 token + KV)
  long_500k    seq 524,288  global_batch 1     -> serve_step

``long_500k`` requires sub-quadratic context handling: SSM/hybrid archs run
natively; attention archs run the *long-context variant* where global
attention layers become sliding-window (window <= 32k) -- per the assignment
rules (dense archs only with a sliding-window variant) and DESIGN.md §4.
"""

from __future__ import annotations

import dataclasses

from repro.models.transformer import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str                   # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

LONG_WINDOW = 32_768


def long_context_variant(cfg: ArchConfig) -> ArchConfig:
    """Sliding-window variant for the 500k decode shape.

    Global attention layers become local with window min(32k, existing).
    SSM/RG-LRU layers are untouched (already O(1)-state). Archs that already
    have a window (gemma2 local layers: 4096, recurrentgemma: 2048) keep it.
    """
    if all(k in ("ssd", "rglru") for k in cfg.pattern):
        return cfg                              # pure-SSM: natively linear
    pattern = tuple("local" if k == "attn" else k for k in cfg.pattern)
    window = cfg.window or LONG_WINDOW
    return dataclasses.replace(cfg, pattern=pattern, window=window)


def needs_long_variant(cfg: ArchConfig) -> bool:
    return any(k == "attn" for k in cfg.pattern)
