"""Deterministic fault injection for the fault-tolerant training loop.

At the paper's scale (2,176 GPUs, 122-second runs) transient faults are the
norm: a half-precision gradient overflows, a data worker hiccups, a node
dies mid-checkpoint, a torus link drops. None of those may abort the job.
This module *simulates* each fault class deterministically so every
recovery path in ``Trainer`` / ``checkpoint`` / ``grad_sync`` is
exercisable in CI on the 8-device CPU mesh (docs/robustness.md).

A :class:`FaultPlan` is pure configuration plus a little bookkeeping for
"fail the first N attempts" semantics. The trainer consults it at three
points:

* ``corrupt_batch(step, batch)``  -- poisons float leaves of the batch with
  NaN/Inf at the chosen steps, which drives non-finite losses/gradients
  through the *real* forward/backward/sync pipeline (exactly how an fp16
  overflow presents), exercising the in-step guard.
* ``wrap_data_fn(data_fn)``       -- raises :class:`TransientDataError`
  from the data function for the first ``data_failures_per_step`` attempts
  at the chosen steps, exercising the retry-with-backoff path.
* ``checkpoint_io_hook``          -- passed to ``checkpoint.save``; raises
  ``OSError`` mid-write (after the payload bytes, before the atomic
  rename) for the chosen save indices, exercising crash-consistency and
  the save retry loop.

``down_axes`` marks mesh axes of the logical torus as "down"; the
strategy-fallback chain in ``grad_sync.resolve_sync_config`` then refuses
strategies whose phase decomposition depends on those axes and degrades
(torus2d -> ring -> psum) instead of aborting.

Beyond the transient classes above, a plan can schedule **permanent**
failures for the elastic recovery layer (``repro.train.elastic``,
docs/robustness.md "Elastic recovery"):

* ``axis_down_events``       -- (axis, step) pairs: the axis is healthy
  until ``step`` and dead from then on. ``down_axes_at(step)`` is the
  health probe the trainer's supervisor polls each step; detection must
  trigger a mid-run strategy re-resolution + checkpoint rollback.
* ``timeout_steps``          -- steps reported as timed out (a straggler);
  consumed per *invocation* so a rolled-back replay of the same step is
  clean, mirroring "the dead worker got replaced".
* ``grad_fault_once=True``   -- NaN/Inf poisoning fires only on the first
  visit to each step, so a rollback past a poisoned streak replays clean.
* ``ckpt_dir_fail_from``     -- every checkpoint write from that save
  index onward fails *persistently* (dead filesystem, not a blip): the
  run must keep training and ``latest_valid`` must keep resolving to the
  last pre-failure checkpoint.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


class TransientDataError(RuntimeError):
    """A data-pipeline failure that is expected to succeed on retry."""


#: Exception classes the trainer treats as retryable when fetching a batch.
RETRYABLE = (TransientDataError, OSError, TimeoutError)


@dataclasses.dataclass
class FaultPlan:
    """A seeded, deterministic schedule of injected faults.

    Steps are *global* step indices (``StagePlan.first_step + i``), so a
    plan replays identically across resumes. Instances carry attempt
    counters, so use a fresh plan per training run.
    """

    seed: int = 0
    nan_grad_steps: tuple[int, ...] = ()     # batch poisoned with NaN
    inf_grad_steps: tuple[int, ...] = ()     # batch poisoned with +Inf
    grad_fault_once: bool = False            # poison each step only once
    data_fail_steps: tuple[int, ...] = ()    # data_fn raises (transient)
    data_failures_per_step: int = 1          # consecutive failures per step
    ckpt_crash_writes: tuple[int, ...] = ()  # save indices crashed mid-file
    ckpt_crashes_per_write: int = 1          # consecutive crashes per save
    ckpt_dir_fail_from: int = -1             # all saves >= idx fail (perm.)
    down_axes: tuple[str, ...] = ()          # torus axes down from step 0
    axis_down_events: tuple[tuple[str, int], ...] = ()  # (axis, down_step)
    timeout_steps: tuple[int, ...] = ()      # steps reported timed out
    timeouts_per_step: int = 1               # consecutive timeouts per step

    def __post_init__(self):
        self._data_attempts: dict[int, int] = {}
        self._timeout_attempts: dict[int, int] = {}
        self._poisoned: set[int] = set()
        self._ckpt_save_idx = -1

    # -- gradient corruption ------------------------------------------------

    def corrupt_batch(self, step: int, batch):
        """Poison one element of every float leaf at a faulted step.

        A single non-finite input element is enough: it propagates through
        the forward pass to the loss and from there into every gradient
        leaf, which is how a real reduced-precision overflow presents after
        the all-reduce.
        """
        if step in self.nan_grad_steps:
            val = float("nan")
        elif step in self.inf_grad_steps:
            val = float("inf")
        else:
            return batch
        if self.grad_fault_once:
            # once-per-step semantics: a rollback past a poisoned streak
            # replays clean (the faulty node was replaced)
            if step in self._poisoned:
                return batch
            self._poisoned.add(step)

        def poison(leaf):
            leaf = jnp.asarray(leaf)
            if not jnp.issubdtype(leaf.dtype, jnp.floating) or leaf.size == 0:
                return leaf
            idx = (self.seed + step) % leaf.size
            return jnp.ravel(leaf).at[idx].set(val).reshape(leaf.shape)

        return jax.tree.map(poison, batch)

    # -- transient data failures --------------------------------------------

    def wrap_data_fn(self, data_fn):
        """Wrap ``data_fn(step, global_batch)`` with injected transient
        failures: the first ``data_failures_per_step`` calls at each step in
        ``data_fail_steps`` raise, subsequent calls pass through."""

        def wrapped(step, global_batch):
            if step in self.data_fail_steps:
                n = self._data_attempts.get(step, 0)
                if n < self.data_failures_per_step:
                    self._data_attempts[step] = n + 1
                    raise TransientDataError(
                        f"injected data failure at step {step} "
                        f"(attempt {n + 1}/{self.data_failures_per_step})")
            return data_fn(step, global_batch)

        return wrapped

    # -- permanent failures (elastic recovery layer) ------------------------

    def down_axes_at(self, step: int) -> tuple[str, ...]:
        """Health probe: every torus axis dead at global ``step``.

        ``down_axes`` are dead from launch; ``axis_down_events`` axes die
        permanently at their scheduled step. The trainer's elastic
        supervisor polls this before each step and treats any *new* axis as
        a permanent failure (docs/robustness.md).
        """
        dead = set(self.down_axes)
        dead.update(a for a, s in self.axis_down_events if step >= s)
        return tuple(sorted(dead))

    def step_timed_out(self, step: int) -> bool:
        """Straggler signal: True for the first ``timeouts_per_step``
        invocations at each step in ``timeout_steps`` (invocation-counted,
        like data failures, so a rolled-back replay runs clean)."""
        if step not in self.timeout_steps:
            return False
        n = self._timeout_attempts.get(step, 0)
        if n >= self.timeouts_per_step:
            return False
        self._timeout_attempts[step] = n + 1
        return True

    # -- checkpoint-write crashes -------------------------------------------

    def checkpoint_io_hook(self, phase: str, attempt: int) -> None:
        """IO hook for ``checkpoint.save`` (phases: begin/payload/manifest).

        Crashes the ``payload`` phase -- bytes written to the tmp file but
        not yet durable/renamed -- of save number ``i`` for every ``i`` in
        ``ckpt_crash_writes``, for the first ``ckpt_crashes_per_write``
        attempts. The atomic-write protocol must leave either the previous
        complete checkpoint or nothing.
        """
        if phase == "begin":
            if attempt == 0:
                self._ckpt_save_idx += 1
            return
        if phase != "payload":
            return
        if 0 <= self.ckpt_dir_fail_from <= self._ckpt_save_idx:
            # persistent: every attempt of every save from here on fails
            # (dead checkpoint filesystem) -- retries must NOT absorb it
            raise OSError(
                f"injected persistent checkpoint-dir failure (save "
                f"#{self._ckpt_save_idx} >= {self.ckpt_dir_fail_from})")
        if (self._ckpt_save_idx in self.ckpt_crash_writes
                and attempt < self.ckpt_crashes_per_write):
            raise OSError(
                f"injected checkpoint-write crash (save "
                f"#{self._ckpt_save_idx}, attempt {attempt})")

    # -- convenience --------------------------------------------------------

    @staticmethod
    def random(seed: int, total_steps: int, *, p_nan: float = 0.05,
               p_data: float = 0.05, n_ckpt_crashes: int = 1) -> "FaultPlan":
        """A random-but-reproducible plan (seeded numpy RNG)."""
        rng = np.random.default_rng(seed)
        steps = np.arange(total_steps)
        nan_steps = tuple(int(s) for s in steps[rng.random(total_steps) < p_nan])
        data_steps = tuple(int(s) for s in steps[rng.random(total_steps) < p_data])
        return FaultPlan(seed=seed, nan_grad_steps=nan_steps,
                         data_fail_steps=data_steps,
                         ckpt_crash_writes=tuple(range(n_ckpt_crashes)))
