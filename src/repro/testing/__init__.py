"""Test-support subsystem: deterministic fault injection (chaos testing)."""

from repro.testing.chaos import FaultPlan, TransientDataError  # noqa: F401
