"""All-reduce algorithms from the paper (§2.2), as named-axis collectives.

Four strategies, each an all-reduce over the data-parallel mesh axes:

  psum          -- single ``lax.psum`` over all DP axes (XLA-native oracle).
  ring          -- flat ring over the flattened DP axes (Baidu ring [14]).
  hierarchical  -- AR inside the horizontal groups, then AR across vertical
                   groups on the FULL volume (Jia et al. [6]).
  torus2d       -- the paper's scheme: reduce-scatter along horizontal rings,
                   all-reduce along vertical rings on 1/X of the volume,
                   all-gather along horizontal rings.

Each strategy has two *lowerings*:

  xla   -- one ``psum_scatter`` / ``psum`` / ``all_gather`` per phase; XLA
           chooses the in-axis algorithm and can overlap phases.
  ring  -- the paper's literal step-by-step ring algorithm built from
           ``lax.ppermute`` (2(n-1) explicit neighbor exchanges); useful to
           audit the collective schedule in HLO and faithful to the paper.

All functions must be called inside ``jax.shard_map`` where the involved
axes are manual. Inputs are the *local* gradient shard; callers are
responsible for the leading dimension being divisible by the relevant ring
sizes (see ``grad_sync.pad_to``).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.compat import axis_size as _axis_size
from repro.core.topology import TorusGrid

AxisName = str | tuple[str, ...]


def _axis_index(axis: AxisName):
    if isinstance(axis, (tuple, list)):
        idx = jnp.int32(0)
        for a in axis:
            idx = idx * _axis_size(a) + lax.axis_index(a)
        return idx
    return lax.axis_index(axis)


def _fwd_perm(n: int) -> list[tuple[int, int]]:
    return [(i, (i + 1) % n) for i in range(n)]


# ---------------------------------------------------------------------------
# Explicit ring primitives (paper's literal algorithm, via ppermute)
# ---------------------------------------------------------------------------

def ring_reduce_scatter(x: jax.Array, axis: AxisName) -> jax.Array:
    """Ring reduce-scatter along ``axis``.

    ``x.shape[0]`` must be divisible by the axis size. Returns the fully
    reduced chunk with *global chunk index* ``(i + 1) % n`` on rank ``i``
    (standard ring convention); compose with :func:`ring_all_gather` which
    accounts for the offset.
    """
    n = _axis_size(axis)
    if n == 1:
        return x
    i = _axis_index(axis)
    csize = x.shape[0] // n

    def chunk(k):
        return lax.dynamic_slice_in_dim(x, (k % n) * csize, csize, 0)

    acc = chunk(i)
    perm = _fwd_perm(n)
    for s in range(n - 1):
        recv = lax.ppermute(acc, axis, perm)
        acc = recv + chunk(i - 1 - s)
    return acc


def ring_all_gather(acc: jax.Array, axis: AxisName) -> jax.Array:
    """Ring all-gather of per-rank chunks produced by ring_reduce_scatter.

    Rank ``i`` holds global chunk ``(i + 1) % n``; after ``n - 1`` neighbor
    exchanges every rank holds the full concatenation in global order.
    """
    n = _axis_size(axis)
    if n == 1:
        return acc
    i = _axis_index(axis)
    csize = acc.shape[0]
    out = jnp.zeros((n * csize,) + acc.shape[1:], acc.dtype)
    out = lax.dynamic_update_slice_in_dim(out, acc, ((i + 1) % n) * csize, 0)
    perm = _fwd_perm(n)
    cur = acc
    for s in range(n - 1):
        cur = lax.ppermute(cur, axis, perm)
        # received from rank i-1-s, which held global chunk (i - s) % n
        out = lax.dynamic_update_slice_in_dim(out, cur, ((i - s) % n) * csize, 0)
    return out


def ring_all_reduce(x: jax.Array, axis: AxisName) -> jax.Array:
    """Flat ring all-reduce: RS then AG, 2(n-1) neighbor exchanges."""
    return ring_all_gather(ring_reduce_scatter(x, axis), axis)


# ---------------------------------------------------------------------------
# Phase implementations with selectable lowering
# ---------------------------------------------------------------------------

def _rs(x, axis, lowering):
    n = _axis_size(axis)
    if n == 1:
        return x
    if lowering == "xla":
        return lax.psum_scatter(x, axis, scatter_dimension=0, tiled=True)
    rs = ring_reduce_scatter(x, axis)
    # re-align to XLA convention (rank i holds chunk i) by rolling one hop
    return lax.ppermute(rs, axis, _fwd_perm(n))


def _ag(x, axis, lowering):
    n = _axis_size(axis)
    if n == 1:
        return x
    if lowering == "xla":
        return lax.all_gather(x, axis, axis=0, tiled=True)
    # incoming follows XLA convention (rank i holds chunk i); roll back one
    # hop to the ring convention then gather.
    back = [((i + 1) % n, i) for i in range(n)]
    return ring_all_gather(lax.ppermute(x, axis, back), axis)


def _ar(x, axis, lowering):
    if _axis_size(axis) == 1:
        return x
    if lowering == "xla":
        return lax.psum(x, axis)
    return ring_all_reduce(x, axis)


# ---------------------------------------------------------------------------
# The four strategies
# ---------------------------------------------------------------------------

def psum_all_reduce(x: jax.Array, grid: TorusGrid, lowering: str = "xla") -> jax.Array:
    del lowering
    return lax.psum(x, grid.axes)


def flat_ring_all_reduce(x: jax.Array, grid: TorusGrid, lowering: str = "xla") -> jax.Array:
    """One flat ring over all DP axes: 2(N-1) steps (paper's Ring baseline)."""
    axes = grid.axes
    if lowering == "xla":
        x = lax.psum_scatter(x, axes, scatter_dimension=0, tiled=True)
        return lax.all_gather(x, axes, axis=0, tiled=True)
    return ring_all_reduce(x, axes)


def hierarchical_all_reduce(x: jax.Array, grid: TorusGrid, lowering: str = "xla") -> jax.Array:
    """Jia et al. [6]: AR inside horizontal groups, then AR across vertical
    groups carrying the FULL gradient volume (the X-times-larger second step
    the paper's §2.2 calls out)."""
    x = _ar(x, grid.h_axes if len(grid.h_axes) > 1 else grid.h_axes[0], lowering)
    if grid.v_axes:
        x = _ar(x, grid.v_axes if len(grid.v_axes) > 1 else grid.v_axes[0], lowering)
    return x


def torus2d_all_reduce(x: jax.Array, grid: TorusGrid, lowering: str = "xla") -> jax.Array:
    """The paper's 2D-Torus all-reduce.

    reduce-scatter along horizontal rings -> all-reduce along vertical rings
    (on 1/X of the bytes) -> all-gather along horizontal rings.
    ``x.shape[0]`` must be divisible by X.
    """
    h = grid.h_axes if len(grid.h_axes) > 1 else grid.h_axes[0]
    x = _rs(x, h, lowering)
    if grid.v_axes:
        v = grid.v_axes if len(grid.v_axes) > 1 else grid.v_axes[0]
        x = _ar(x, v, lowering)
    return _ag(x, h, lowering)


STRATEGIES = {
    "psum": psum_all_reduce,
    "ring": flat_ring_all_reduce,
    "hierarchical": hierarchical_all_reduce,
    "torus2d": torus2d_all_reduce,
}


def all_reduce(x: jax.Array, grid: TorusGrid, strategy: str = "torus2d",
               lowering: str = "xla") -> jax.Array:
    """Dispatch an all-reduce (sum) of ``x`` over the grid's DP axes."""
    try:
        fn = STRATEGIES[strategy]
    except KeyError:
        raise ValueError(f"unknown strategy {strategy!r}; options {sorted(STRATEGIES)}") from None
    return fn(x, grid, lowering)


# ---------------------------------------------------------------------------
# Analytic cost model (paper §2.2 step counts; used by benchmarks/allreduce)
# ---------------------------------------------------------------------------

def comm_cost_model(strategy: str, nbytes: int, x: int, y: int,
                    link_bw: float, latency: float) -> dict:
    """Alpha-beta cost of one all-reduce of ``nbytes`` on an X x Y torus.

    Returns steps, bytes-on-wire per device, and estimated seconds. This is
    the model behind the paper's 2(X-1)-vs-2(N-1) argument and the
    hierarchical comparison (Table 2/6 analogue).
    """
    n = x * y
    if strategy == "ring":
        steps = 2 * (n - 1)
        wire = 2.0 * nbytes * (n - 1) / n
    elif strategy == "hierarchical":
        steps = 2 * (x - 1) + 2 * (y - 1)
        wire = 2.0 * nbytes * (x - 1) / x + 2.0 * nbytes * (y - 1) / y
    elif strategy == "torus2d":
        steps = 2 * (x - 1) + 2 * (y - 1)
        wire = 2.0 * nbytes * (x - 1) / x + 2.0 * (nbytes / x) * (y - 1) / y
    elif strategy == "psum":  # model as a good tree/ring hybrid == torus
        steps = 2 * (x - 1) + 2 * (y - 1)
        wire = 2.0 * nbytes * (x - 1) / x + 2.0 * (nbytes / x) * (y - 1) / y
    else:
        raise ValueError(strategy)
    seconds = steps * latency + wire / link_bw
    return {"strategy": strategy, "steps": steps, "wire_bytes": wire, "seconds": seconds}


def bucketed_comm_cost_model(strategy: str, nbytes: int, bucket_bytes: int,
                             x: int, y: int, link_bw: float, latency: float,
                             backward_seconds: float = 0.0) -> dict:
    """Alpha-beta cost of a *bucketed* gradient exchange overlapped with
    backprop (the schedule ``grad_sync.sync_tree`` emits for
    ``bucket_bytes > 0``).

    The gradient is split into ``k = ceil(nbytes / bucket_bytes)`` buckets.
    Every bucket pays the full per-step latency (steps x alpha -- the cost
    of more buckets) but bucket ``i`` becomes ready at
    ``backward_seconds * (i + 1) / k`` (gradients stream out of backprop in
    reverse-layer order at roughly uniform rate) and its exchange runs as
    soon as both the gradients and the link are free -- the overlap win.

    Returns::

        num_buckets, per_bucket (comm_cost_model dicts),
        serial_seconds   -- sum of bucket costs, no overlap (lower bound on
                            the fused latency had we not overlapped),
        exposed_seconds  -- comm time NOT hidden behind backprop
                            (finish of last bucket - backward_seconds),
        fused_exposed_seconds -- the single-buffer baseline: the whole
                            exchange starts after backward, fully exposed,
        overlap_win_seconds -- fused_exposed - exposed.

    With ``backward_seconds=0`` this degenerates to the pure serial
    latency-vs-bandwidth tradeoff (more buckets strictly worse).
    """
    if bucket_bytes <= 0 or bucket_bytes >= nbytes:
        k = 1
        sizes = [nbytes]
    else:
        k = -(-int(nbytes) // int(bucket_bytes))
        sizes = [bucket_bytes] * (k - 1) + [nbytes - bucket_bytes * (k - 1)]

    per_bucket = [comm_cost_model(strategy, s, x, y, link_bw, latency)
                  for s in sizes]
    serial = sum(c["seconds"] for c in per_bucket)

    # pipeline simulation: one link, buckets issued in ready order
    t = 0.0
    for i, c in enumerate(per_bucket):
        ready = backward_seconds * (i + 1) / k
        t = max(t, ready) + c["seconds"]
    exposed = t - backward_seconds

    fused = comm_cost_model(strategy, nbytes, x, y, link_bw, latency)
    return {
        "strategy": strategy,
        "num_buckets": k,
        "bucket_bytes": bucket_bytes,
        "per_bucket": per_bucket,
        "serial_seconds": serial,
        "exposed_seconds": exposed,
        "fused_exposed_seconds": fused["seconds"],
        "overlap_win_seconds": fused["seconds"] - exposed,
    }
