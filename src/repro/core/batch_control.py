"""Batch-size control: turning a BatchSchedule into an executable training
plan (paper §2.1 -- "a predetermined batch-size adjustment scheduling is
employed during the training").

Changing the per-worker batch size changes the global batch shape, which in
JAX means a new compiled step. The plan enumerates stages; the trainer jits
one step per stage (compile cache keyed by shape, so revisiting a size is
free). LR/momentum schedules are evaluated per-step from the *fractional
epoch*, which advances by global_batch/dataset_size each step -- exactly the
paper's `epoch = ProcessedSamples / DataSize`.
"""

from __future__ import annotations

import dataclasses
import math

from repro.core.schedules import BatchSchedule, BatchStage


@dataclasses.dataclass(frozen=True)
class StagePlan:
    stage: BatchStage
    global_batch: int
    num_steps: int
    first_step: int
    start_epoch: float


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    stages: tuple[StagePlan, ...]
    dataset_size: int
    n_workers: int

    @property
    def total_steps(self) -> int:
        return sum(s.num_steps for s in self.stages)


def build_plan(schedule: BatchSchedule, *, dataset_size: int,
               n_workers: int, max_steps: int | None = None) -> TrainPlan:
    plans = []
    step = 0
    for st in schedule.stages:
        gb = st.global_batch(n_workers)
        span = st.end_epoch - st.start_epoch
        n = math.ceil(span * dataset_size / gb)
        if max_steps is not None:
            n = min(n, max(0, max_steps - step))
        plans.append(StagePlan(stage=st, global_batch=gb, num_steps=n,
                               first_step=step, start_epoch=st.start_epoch))
        step += n
        if max_steps is not None and step >= max_steps:
            break
    return TrainPlan(stages=tuple(plans), dataset_size=dataset_size,
                     n_workers=n_workers)


def epoch_of(plan: TrainPlan, stage: StagePlan, step_in_stage: int) -> float:
    """Fractional epoch at a given step (paper's ProcessedSamples/DataSize)."""
    return stage.start_epoch + step_in_stage * stage.global_batch / plan.dataset_size
