"""LARS optimizer (You et al. [10]), as used by the paper (§3.2).

Paper settings: coefficient (trust ratio eta) = 0.01, eps = 1e-6, momentum
SGD underneath, and -- critically -- *all LARS computation in FP32* because
the trust ratio (norm ratios) needs more dynamic range than half precision.
Weight decay is applied inside the LARS norm (You et al. eq. 4).

The update for parameter w with gradient g (already averaged across the DP
grid by grad_sync):

    local_lr = eta * ||w|| / (||g|| + wd * ||w|| + eps)
    v        = m * v + local_lr * global_lr * (g + wd * w)
    w        = w - v

Bias/BN parameters are excluded from LARS scaling and weight decay
(standard practice in [10] and every reproduction, incl. the paper's NNL
code): they use plain momentum SGD.

A fused Pallas kernel for the elementwise part lives in
``repro.kernels.lars_update``; this module is the optimizer logic and uses
the kernel via ``use_kernel=True`` (ref path by default so CPU tests are
oracle-exact).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LARSConfig:
    eta: float = 0.01            # paper: "coefficient of 0.01"
    eps: float = 1e-6            # paper default
    weight_decay: float = 5e-5   # You et al. ImageNet setting
    skip_tags: tuple[str, ...] = ("bias", "bn", "scale", "norm", "embed_norm")
    use_kernel: bool = False     # route elementwise update through Pallas
    nesterov: bool = False


def _is_skip(path, cfg: LARSConfig) -> bool:
    ps = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()
    return any(t in ps for t in cfg.skip_tags)


def init(params) -> dict:
    """Momentum buffers, fp32 (master-precision) like the params."""
    return {"momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def update(params, grads, opt_state, *, lr, momentum, cfg: LARSConfig = LARSConfig()):
    """One LARS step. params/grads may be bf16; all math is fp32 (paper §3.2).

    lr, momentum are scalars (possibly traced -- schedules evaluate per
    step). Returns (new_params, new_opt_state).
    """
    mom_tree = opt_state["momentum"]

    grads_flat = jax.tree_util.tree_flatten_with_path(grads)[0]
    params_flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    moms = jax.tree_util.tree_leaves(mom_tree)

    new_p, new_m = [], []
    for (path, p), (_, g), v in zip(params_flat, grads_flat, moms):
        p32 = p.astype(jnp.float32)
        g32 = g.astype(jnp.float32)
        if _is_skip(path, cfg):
            # plain momentum SGD, no trust ratio, no weight decay
            v_new = momentum * v + lr * g32
        else:
            if cfg.use_kernel:
                from repro.kernels import ops as kops
                p_out, v_new = kops.lars_update(
                    p32, g32, v, lr=lr, mom=momentum, eta=cfg.eta,
                    weight_decay=cfg.weight_decay, eps=cfg.eps)
                new_p.append(p_out.astype(p.dtype))
                new_m.append(v_new)
                continue
            w_norm = jnp.linalg.norm(p32)
            g_norm = jnp.linalg.norm(g32)
            trust = jnp.where(
                (w_norm > 0) & (g_norm > 0),
                cfg.eta * w_norm / (g_norm + cfg.weight_decay * w_norm + cfg.eps),
                1.0)
            upd = g32 + cfg.weight_decay * p32
            v_new = momentum * v + (trust * lr) * upd
        if cfg.nesterov:
            step = momentum * v_new + (v_new - momentum * v)
        else:
            step = v_new
        new_p.append((p32 - step).astype(p.dtype))
        new_m.append(v_new)

    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    mom_out = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(mom_tree), new_m)
    return params_out, {"momentum": mom_out}


# -- plain momentum-SGD baseline (reference configuration uses LARS too, but
#    benchmarks compare against this for the no-LARS ablation) --------------

def sgd_init(params):
    return {"momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}


def sgd_update(params, grads, opt_state, *, lr, momentum, weight_decay=0.0):
    def upd(p, g, v):
        g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        v_new = momentum * v + g32
        return (p.astype(jnp.float32) - lr * v_new).astype(p.dtype), v_new

    flat = jax.tree.map(upd, params, grads, opt_state["momentum"])
    new_p = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    return new_p, {"momentum": new_v}
