"""Gradient synchronization over pytrees (the paper's technique as a
first-class framework feature).

Two execution modes, chosen by ``fuse``:

* ``fuse=True`` (paper-faithful, pure data-parallel): leaves are flattened
  into fused comm buffers (mixed-precision: comm-dtype group + fp32 group,
  §3.2 of the paper keeps BN statistics and LARS in fp32), padded to the
  ring size, exchanged with the selected strategy, and scattered back. This
  is what the paper's NCCL implementation does with bucket fusion, and it is
  only legal when the leaves are replicated over the model axis (ResNet /
  pure-DP configs).

  ``bucket_bytes`` controls *how many* fused buffers there are:

  - ``bucket_bytes=0`` (legacy): one buffer per precision group -- the
    exchange can only start after the full backward pass.
  - ``bucket_bytes>0``: each precision group is greedily partitioned into
    size-targeted buckets, **ordered in reverse-backprop order** (the pytree
    flatten order follows the forward pass, so the *last* leaves get their
    gradients *first* during backprop). One strategy-dispatch all-reduce is
    issued per bucket, earliest-ready bucket first, so XLA's latency-hiding
    scheduler can overlap each bucket's 2D-Torus exchange with the
    remaining backward compute. See docs/gradient_sync.md for the layout
    contract and ``collectives.bucketed_comm_cost_model`` for the
    latency-vs-overlap tradeoff model.

* ``fuse=False`` (tensor/fsdp-sharded models): each *large* leaf is
  synchronized independently along its leading dimension (padded to X), so
  model-axis sharding on other dimensions is untouched by the exchange.
  Small leaves (below ``small_leaf_threshold`` elements -- BN statistics,
  scales, biases, which the sharding rules replicate) are latency-bound,
  so instead of one tiny ``psum`` per leaf they are **grouped**: same
  comm-dtype small leaves are raveled into shared buffers (partitioned by
  ``partition_buckets`` when ``bucket_bytes > 0``) and exchanged with one
  ``psum`` per group -- the same latency amortization the fused path gets,
  without touching the model-sharded large leaves.

``bucket_bytes`` may also be the string ``"auto"``: ``resolve_sync_config``
replaces it with a tuned value from ``repro.core.autotune`` (analytic knee
of the cost model, refined against the gradient size when ``params_like``
is given) -- re-resolution after an elastic downgrade re-tunes for the
degraded strategy. ``sync_tree`` / ``bucket_layout`` require the resolved
integer.

Both modes must run inside ``shard_map`` (see repro.compat) where the grid
axes are manual.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import collectives
from repro.core.topology import TorusGrid


#: ``bucket_bytes`` sentinel: resolve the value via ``repro.core.autotune``
#: at ``resolve_sync_config`` time instead of hand-setting a constant.
AUTO = "auto"


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "torus2d"           # psum | ring | hierarchical | torus2d
    lowering: str = "xla"               # xla | ring (explicit ppermute)
    comm_dtype: Any = jnp.bfloat16      # paper: fp16; TPU-native: bf16
    fp32_paths: tuple[str, ...] = ("batch_stats", "bn", "scale", "bias")
    fuse: bool = True
    mean: bool = True
    small_leaf_threshold: int = 2048    # below: grouped psum (latency-bound)
    bucket_bytes: int | str = 0         # 0: single fused buffer per group;
                                        # >0: size-targeted comm buckets;
                                        # "auto": tuned at resolve time
    reverse_order: bool = True          # issue buckets reverse-backprop first


def _require_resolved(bucket_bytes) -> int:
    """``bucket_bytes`` as an int; rejects the unresolved ``"auto"``."""
    if isinstance(bucket_bytes, bool) or not isinstance(bucket_bytes, int):
        raise ValueError(
            f"bucket_bytes={bucket_bytes!r} is not resolved -- pass the "
            "config through resolve_sync_config (which replaces "
            f"bucket_bytes={AUTO!r} with an autotuned value, "
            "docs/gradient_sync.md) before sync_tree/bucket_layout")
    return bucket_bytes


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def _world(grid: TorusGrid) -> int:
    size = 1
    for a in grid.axes:
        size *= compat.axis_size(a)
    return size


def _ring_multiple(grid: TorusGrid) -> int:
    """Leading-dim divisibility required by the strategies' scatter phases."""
    x = 1
    for a in grid.h_axes:
        x *= compat.axis_size(a)
    y = 1
    for a in grid.v_axes:
        y *= compat.axis_size(a)
    # torus2d ring lowering reduce-scatters the 1/X chunk again over Y
    return x * y


# ---------------------------------------------------------------------------
# Bucket partitioning (pure python; also used by benchmarks and the dry-run
# HLO audit, so it must stay trace-free)
# ---------------------------------------------------------------------------

def partition_buckets(leaf_bytes: Sequence[int], bucket_bytes: int) -> list[list[int]]:
    """Greedy partition of leaf indices into size-targeted buckets.

    Walks the leaves in the given order and closes a bucket as soon as its
    cumulative size reaches ``bucket_bytes`` (so each bucket is at least the
    target size except the last, and a single oversized leaf forms its own
    bucket). A trailing bucket smaller than *half* the target is merged
    into its predecessor: the leftover tail (worst case one tiny leaf)
    would otherwise become a pure-latency straggler exchange issued last,
    exactly where it delays the step. ``bucket_bytes <= 0`` returns one
    bucket with everything -- the legacy fully-fused layout.
    """
    idx = list(range(len(leaf_bytes)))
    if bucket_bytes <= 0:
        return [idx] if idx else []
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in idx:
        cur.append(i)
        cur_bytes += leaf_bytes[i]
        if cur_bytes >= bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(cur)
    if len(buckets) >= 2 and 2 * sum(
            leaf_bytes[i] for i in buckets[-1]) < bucket_bytes:
        buckets[-2].extend(buckets.pop())
    return buckets


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def _precision_groups(leaves_p, cfg: GradSyncConfig) -> list[tuple[list[int], Any]]:
    """Split leaf indices into (comm-dtype, fp32) groups, preserving order.

    The paper exchanges the bulk of the gradient in half precision but keeps
    BN statistics / scales / biases (and any fp32 vector leaf) in fp32;
    buckets never mix the two groups.
    """
    comm_idx, fp32_idx = [], []
    for k, (path, leaf) in enumerate(leaves_p):
        ps = _path_str(path)
        if any(tag in ps for tag in cfg.fp32_paths) or \
                leaf.dtype == jnp.float32 and leaf.ndim <= 1:
            fp32_idx.append(k)
        else:
            comm_idx.append(k)
    return [(comm_idx, cfg.comm_dtype), (fp32_idx, jnp.float32)]


def _per_leaf_dtype(path, leaf, cfg: GradSyncConfig):
    """Comm dtype of one leaf on the ``fuse=False`` path (tag match only --
    mirrors the historical per-leaf classification, which unlike
    ``_precision_groups`` does not special-case fp32 vectors)."""
    fp32 = any(tag in _path_str(path) for tag in cfg.fp32_paths)
    return ("fp32", jnp.float32) if fp32 else ("comm", cfg.comm_dtype)


def _per_leaf_plan(leaves_p, cfg: GradSyncConfig):
    """Exchange plan for the ``fuse=False`` path.

    Returns ``(large, groups)``: ``large`` is ``[(leaf_idx, dtype), ...]``
    in issue order (reverse-backprop when ``cfg.reverse_order``) -- one
    strategy exchange each, preserving any model-axis sharding on trailing
    dims. ``groups`` is ``[{"group", "dtype", "buckets": [[leaf_idx...]]}]``
    -- small leaves (below ``small_leaf_threshold`` elements, or scalars)
    grouped by precision group, partitioned by ``partition_buckets``
    (single shared bucket when ``bucket_bytes <= 0``), one ``psum`` per
    bucket. Grouping ravels leaves, so it relies on small leaves being
    replicated over non-grid axes -- which the sharding rules guarantee
    (1-D scales/biases/BN stats are never model-sharded).
    """
    bucket_bytes = _require_resolved(cfg.bucket_bytes)
    large: list[tuple[int, Any]] = []
    small: dict[tuple[str, Any], list[int]] = {}
    for k, (path, leaf) in enumerate(leaves_p):
        name, dtype = _per_leaf_dtype(path, leaf, cfg)
        if leaf.size < cfg.small_leaf_threshold or leaf.ndim == 0:
            small.setdefault((name, dtype), []).append(k)
        else:
            large.append((k, dtype))
    if cfg.reverse_order:
        large.reverse()
    groups = []
    for (name, dtype), ks in small.items():
        order = list(reversed(ks)) if cfg.reverse_order else list(ks)
        sizes = [leaves_p[k][1].size * _itemsize(dtype) for k in order]
        groups.append({
            "group": name, "dtype": dtype,
            "buckets": [[order[i] for i in bucket]
                        for bucket in partition_buckets(sizes, bucket_bytes)],
        })
    return large, groups


def bucket_layout(grads, cfg: GradSyncConfig = GradSyncConfig()) -> list[dict]:
    """The exchange schedule ``sync_tree`` will issue, as metadata.

    Returns one dict per exchange in **issue order** with keys ``group``
    ("comm"|"fp32"), ``dtype``, ``nbytes``, ``num_leaves``, ``paths``, and
    ``mode``: ``"fused"`` buckets for the ``fuse=True`` path; for
    ``fuse=False`` one ``"per_leaf"`` entry per large leaf plus
    ``"grouped"`` entries for the shared small-leaf buckets. Works on
    concrete arrays or ShapeDtypeStructs; never traces. Used by the
    dry-run audit and the bucket-sweep benchmark to cross-check the HLO
    against the intended schedule.
    """
    leaves_p, _ = jax.tree_util.tree_flatten_with_path(grads)
    out = []
    if cfg.fuse:
        bucket_bytes = _require_resolved(cfg.bucket_bytes)
        for name, (idx_group, dtype) in zip(
                ("comm", "fp32"), _precision_groups(leaves_p, cfg)):
            if not idx_group:
                continue
            order = (list(reversed(idx_group)) if cfg.reverse_order
                     else list(idx_group))
            sizes = [leaves_p[k][1].size * _itemsize(dtype) for k in order]
            for bucket in partition_buckets(sizes, bucket_bytes):
                ks = [order[i] for i in bucket]
                out.append({
                    "group": name,
                    "dtype": np.dtype(dtype).name,
                    "nbytes": sum(sizes[i] for i in bucket),
                    "num_leaves": len(ks),
                    "paths": [_path_str(leaves_p[k][0]) for k in ks],
                    "mode": "fused",
                })
        return out
    large, groups = _per_leaf_plan(leaves_p, cfg)
    for k, dtype in large:
        path, leaf = leaves_p[k]
        name, _ = _per_leaf_dtype(path, leaf, cfg)
        out.append({
            "group": name, "dtype": np.dtype(dtype).name,
            "nbytes": leaf.size * _itemsize(dtype), "num_leaves": 1,
            "paths": [_path_str(path)], "mode": "per_leaf",
        })
    for grp in groups:
        for ks in grp["buckets"]:
            out.append({
                "group": grp["group"],
                "dtype": np.dtype(grp["dtype"]).name,
                "nbytes": sum(leaves_p[k][1].size * _itemsize(grp["dtype"])
                              for k in ks),
                "num_leaves": len(ks),
                "paths": [_path_str(leaves_p[k][0]) for k in ks],
                "mode": "grouped",
            })
    return out


def sync_tree(grads, grid: TorusGrid, cfg: GradSyncConfig = GradSyncConfig()):
    """All-reduce (mean if cfg.mean) a gradient pytree over the DP grid."""
    _require_resolved(cfg.bucket_bytes)
    if cfg.fuse:
        return _sync_fused(grads, grid, cfg)
    return _sync_per_leaf(grads, grid, cfg)


def record_bucket_metrics(grads_like, cfg: GradSyncConfig,
                          registry) -> list[dict]:
    """Publish the bucket schedule as gauges on a metrics registry
    (repro.obs.metrics; docs/observability.md has the name table).

    ``sync_tree`` itself runs inside jit/shard_map, so per-bucket numbers
    can't be recorded at execution time -- but the schedule is a pure
    host-side function of the gradient *structure* (``bucket_layout``),
    which the trainer knows the moment it resolves the sync config. Called
    with the params tree (same treedef as the grads) and the resolved
    config, this sets, for the fused path:

    * ``grad_sync/num_buckets``            -- buckets in issue order
    * ``grad_sync/total_nbytes``           -- bytes over all buckets
    * ``grad_sync/bucketNN/nbytes``        -- per-bucket comm payload
    * ``grad_sync/bucketNN/num_leaves``    -- leaves packed into bucket NN

    and for the per-leaf ``fuse=False`` path:

    * ``grad_sync/num_exchanges``          -- total exchanges (both paths)
    * ``grad_sync/total_nbytes``           -- bytes over all exchanges
    * ``grad_sync/per_leaf_exchanges``     -- large-leaf strategy exchanges
    * ``grad_sync/grouped_buckets``        -- shared small-leaf psum buckets
    * ``grad_sync/bucketNN/...``           -- the grouped buckets only

    Every call first drops **all** ``grad_sync/`` metrics from the registry
    (``MetricsRegistry.remove_prefix``): an elastic re-resolve can change
    the bucket count or switch sync paths entirely, and gauges from the
    previous schedule must not linger and get exported as current.

    The multidevice obs smoke cross-checks the gauge count against
    ``hlo_stats.bucket_audit`` on the compiled step -- gauges describe the
    *intended* schedule, the audit the *compiled* one; they must agree.
    Returns the layout (issue order); [] only when ``registry`` is None.
    """
    if registry is None:
        return []
    remove_prefix = getattr(registry, "remove_prefix", None)
    if remove_prefix is not None:
        remove_prefix("grad_sync/")
    layout = bucket_layout(grads_like, cfg)
    registry.gauge("grad_sync/num_exchanges").set(len(layout))
    registry.gauge("grad_sync/total_nbytes").set(
        sum(b["nbytes"] for b in layout))
    if cfg.fuse:
        registry.gauge("grad_sync/num_buckets").set(len(layout))
        for i, b in enumerate(layout):
            registry.gauge(f"grad_sync/bucket{i:02d}/nbytes").set(b["nbytes"])
            registry.gauge(
                f"grad_sync/bucket{i:02d}/num_leaves").set(b["num_leaves"])
        return layout
    grouped = [b for b in layout if b["mode"] == "grouped"]
    registry.gauge("grad_sync/per_leaf_exchanges").set(
        sum(1 for b in layout if b["mode"] == "per_leaf"))
    registry.gauge("grad_sync/grouped_buckets").set(len(grouped))
    for i, b in enumerate(grouped):
        registry.gauge(f"grad_sync/bucket{i:02d}/nbytes").set(b["nbytes"])
        registry.gauge(
            f"grad_sync/bucket{i:02d}/num_leaves").set(b["num_leaves"])
    return layout


# ---------------------------------------------------------------------------
# Graceful degradation: strategy fallback chain (docs/robustness.md)
# ---------------------------------------------------------------------------

#: Ordered degradation chain per strategy (2d_torus -> ... -> ring -> psum).
#: Later entries trade the paper's bandwidth-optimal schedule for
#: robustness: hierarchical (xla lowering) is all-reduce-only so it lowers
#: everywhere torus2d cannot, the flat ring is a single in-axis exchange
#: XLA may reroute around a dead link, and psum is the native all-reduce
#: that always lowers.
FALLBACK_CHAINS: dict[str, tuple[str, ...]] = {
    "torus2d": ("torus2d", "hierarchical", "ring", "psum"),
    "hierarchical": ("hierarchical", "ring", "psum"),
    "ring": ("ring", "psum"),
    "psum": ("psum",),
}


def fallback_chain(strategy: str) -> tuple[str, ...]:
    return FALLBACK_CHAINS.get(strategy, (strategy, "psum"))


def _strategy_viable(strategy: str, lowering: str, grid: TorusGrid, mesh,
                     manual_axes, down_axes=(), probe: bool = True):
    """(viable, reason). ``reason`` explains the rejection when not viable.

    Three checks, cheapest first:

    1. *Down axes*: torus2d / hierarchical decompose the reduction into
       per-axis phases that map onto physical link dimensions -- a down
       torus axis kills them. The flat strategies (ring with the xla
       lowering, psum) leave routing to the compiler/fabric and survive;
       the explicit ppermute ring lowering pins neighbor links, so it is
       rejected too.
    2. *Partial-manual shard_map*: on jaxlib < 0.5 the SPMD partitioner
       hard-aborts (uncatchable F-check) on scatter/gather/permute
       collectives when some mesh axes stay auto
       (``compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES``); only all-reduce-
       only strategies (psum, xla-lowered hierarchical) are safe there.
    3. *Trace probe*: a tiny ``jax.eval_shape`` of the strategy under the
       real mesh/grid catches anything else (missing primitives, bad axis
       factorization) without allocating or compiling. Only run when the
       shard_map is fully manual -- see (2) for why probing partial-manual
       combos is not safe.
    """
    down = set(down_axes) & set(grid.axes)
    if down:
        if strategy in ("torus2d", "hierarchical"):
            return False, (f"torus axis(es) {sorted(down)} down: per-axis "
                           "phase decomposition unavailable")
        if lowering == "ring":
            return False, (f"axis(es) {sorted(down)} down: explicit ppermute "
                           "ring pins dead neighbor links")

    manual = set(manual_axes)
    partial = bool(set(mesh.axis_names) - manual)
    if partial and not compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES:
        ar_only = strategy == "psum" or (strategy == "hierarchical"
                                         and lowering == "xla")
        if not ar_only:
            return False, ("partial-manual shard_map on this jaxlib only "
                           "lowers all-reduce collectives (jax >= 0.5 "
                           "needed for scatter/gather/permute)")

    if probe and not partial:
        try:
            mult = 1
            for a in grid.axes:
                mult *= int(mesh.shape[a])

            def _probe_sync(x):
                return collectives.all_reduce(x, grid, strategy, lowering)

            smapped = compat.shard_map(
                _probe_sync, mesh=mesh,
                in_specs=jax.sharding.PartitionSpec(),
                out_specs=jax.sharding.PartitionSpec(),
                axis_names=frozenset(manual), check_vma=False)
            jax.eval_shape(
                smapped, jax.ShapeDtypeStruct((mult,), jnp.float32))
        except Exception as e:  # noqa: BLE001 -- any trace failure degrades
            return False, f"trace probe failed: {type(e).__name__}: {e}"
    return True, ""


def _resolve_bucket_bytes(cfg: GradSyncConfig, grid: TorusGrid, mesh,
                          params_like, hw, context: str
                          ) -> tuple[GradSyncConfig, list[dict]]:
    """Replace ``bucket_bytes="auto"`` with an autotuned value.

    Runs *after* the strategy fallback chain, so the tuned size matches the
    strategy that will actually execute -- an elastic downgrade (say
    torus2d -> ring, 4x the steps hence ~4x the knee) re-tunes here on
    re-resolution. With ``params_like`` (params/grads tree or
    ShapeDtypeStructs) the pick minimizes the cost model's exposed comm
    time over a grid around the analytic knee; without it, the knee alone.
    """
    if cfg.bucket_bytes != AUTO:
        return cfg, []
    from repro.core import autotune
    hw = hw or autotune.TPU_POD_HW
    x, y = grid.sizes(mesh)
    total_bytes = None
    if params_like is not None:
        layout = bucket_layout(
            params_like, dataclasses.replace(cfg, bucket_bytes=0))
        total_bytes = sum(b["nbytes"] for b in layout)
    rec = autotune.recommend_bucket_bytes(cfg.strategy, x, y, hw,
                                          total_bytes=total_bytes)
    event = {"event": "bucket_autotune", "context": context,
             "strategy": cfg.strategy, "mode": rec["mode"],
             "bucket_bytes": rec["bucket_bytes"],
             "analytic_knee_bytes": rec["analytic_knee_bytes"],
             "total_bytes": total_bytes, "hw": rec["hw"]["name"]}
    if rec["mode"] == "cost_model":
        event["exposed_seconds"] = rec["exposed_seconds"]
        event["num_buckets"] = rec["num_buckets"]
    return dataclasses.replace(cfg, bucket_bytes=rec["bucket_bytes"]), [event]


def resolve_sync_config(cfg: GradSyncConfig, grid: TorusGrid, mesh,
                        manual_axes, down_axes=(), probe: bool = True,
                        context: str = "startup", params_like=None,
                        hw=None) -> tuple[GradSyncConfig, list[dict]]:
    """Walk ``cfg.strategy``'s fallback chain; return the first viable
    config plus the rejection/downgrade events (for history/logging).

    Never raises: psum terminates every chain and always lowers. A
    downgrade is an event, not an error -- the job keeps training
    (docs/robustness.md). ``context`` tags the events with *when* the
    resolution ran: ``"startup"`` (job launch) or ``"elastic"`` (mid-run
    re-resolution after a permanent failure, ``repro.train.elastic``).

    ``bucket_bytes="auto"`` is resolved here too (after the strategy is
    fixed, so the tuned size matches the executing schedule), against
    ``params_like`` (the gradient structure; optional) and ``hw`` (an
    ``autotune.HardwareModel``; defaults to the paper-target pod). The
    pick is attached as a ``bucket_autotune`` event.
    """
    events: list[dict] = []
    chain = fallback_chain(cfg.strategy)
    for strategy in chain:
        ok, reason = _strategy_viable(strategy, cfg.lowering, grid, mesh,
                                      manual_axes, down_axes, probe)
        if ok:
            if strategy != cfg.strategy:
                events.append({
                    "event": "grad_sync_downgrade",
                    "from": cfg.strategy, "to": strategy,
                    "context": context,
                })
            resolved = dataclasses.replace(cfg, strategy=strategy)
            resolved, tune_events = _resolve_bucket_bytes(
                resolved, grid, mesh, params_like, hw, context)
            return resolved, events + tune_events
        events.append({"event": "grad_sync_strategy_rejected",
                       "strategy": strategy, "reason": reason,
                       "context": context})
    # unreachable in practice (psum has no rejection path), but never abort
    events.append({"event": "grad_sync_downgrade",
                   "from": cfg.strategy, "to": "psum", "context": context})
    resolved = dataclasses.replace(cfg, strategy="psum")
    resolved, tune_events = _resolve_bucket_bytes(
        resolved, grid, mesh, params_like, hw, context)
    return resolved, events + tune_events


def _sync_fused(grads, grid: TorusGrid, cfg: GradSyncConfig):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(grads)
    if not leaves_p:
        return grads
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0
    mult = _ring_multiple(grid)

    leaves = [leaf for _, leaf in leaves_p]
    out: list = [None] * len(leaves)

    for idx_group, dtype in _precision_groups(leaves_p, cfg):
        if not idx_group:
            continue
        # reverse-backprop order: tree-flatten order tracks the forward
        # pass, so the last leaves' grads materialize first in backward --
        # their bucket is issued first and overlaps the rest of backprop.
        order = list(reversed(idx_group)) if cfg.reverse_order else list(idx_group)
        sizes = [leaves[k].size * _itemsize(dtype) for k in order]
        for bucket in partition_buckets(sizes, cfg.bucket_bytes):
            ks = [order[i] for i in bucket]
            flat = jnp.concatenate(
                [jnp.ravel(leaves[k]).astype(dtype) for k in ks])
            # pre-scale: keeps fp16/bf16 partial sums in range (paper
            # exchanges in half precision)
            flat = flat * jnp.asarray(scale, dtype)
            padded = _pad_to(flat, mult)
            reduced = collectives.all_reduce(padded, grid, cfg.strategy,
                                             cfg.lowering)
            reduced = reduced[: flat.shape[0]]
            off = 0
            for k in ks:
                size = leaves[k].size
                out[k] = reduced[off: off + size].reshape(
                    leaves[k].shape).astype(leaves[k].dtype)
                off += size

    return jax.tree_util.tree_unflatten(treedef, out)


def _sync_per_leaf(grads, grid: TorusGrid, cfg: GradSyncConfig):
    from jax import lax
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(grads)
    if not leaves_p:
        return grads
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0
    mult = _ring_multiple(grid)
    leaves = [leaf for _, leaf in leaves_p]
    out: list = [None] * len(leaves)

    large, groups = _per_leaf_plan(leaves_p, cfg)
    # Large (possibly model-sharded) leaves: one strategy exchange each
    # along the leading dim, in reverse-backprop issue order.
    for k, dtype in large:
        g = leaves[k]
        orig_dtype = g.dtype
        g = g.astype(dtype) * jnp.asarray(scale, dtype)
        n0 = g.shape[0]
        g = _pad_to(g, mult)
        g = collectives.all_reduce(g, grid, cfg.strategy, cfg.lowering)
        out[k] = g[:n0].astype(orig_dtype)

    # Small replicated leaves: ravel into shared buffers per precision
    # group (partitioned by bucket_bytes), one latency-amortized psum per
    # bucket instead of one per leaf.
    for grp in groups:
        dtype = grp["dtype"]
        for ks in grp["buckets"]:
            flat = jnp.concatenate(
                [jnp.ravel(leaves[k]).astype(dtype) for k in ks])
            flat = flat * jnp.asarray(scale, dtype)
            reduced = lax.psum(flat, grid.axes)
            off = 0
            for k in ks:
                size = leaves[k].size
                out[k] = reduced[off: off + size].reshape(
                    leaves[k].shape).astype(leaves[k].dtype)
                off += size

    return jax.tree_util.tree_unflatten(treedef, out)
