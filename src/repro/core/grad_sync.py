"""Gradient synchronization over pytrees (the paper's technique as a
first-class framework feature).

Two execution modes, chosen by ``fuse``:

* ``fuse=True`` (paper-faithful, pure data-parallel): every leaf is
  flattened into a single fused buffer (mixed-precision: comm-dtype group +
  fp32 group, §3.2 of the paper keeps BN statistics and LARS in fp32),
  padded to the ring size, exchanged with the selected strategy, and
  scattered back. This is what the paper's NCCL implementation does with
  bucket fusion, and it is only legal when the leaves are replicated over
  the model axis (ResNet / pure-DP configs).

* ``fuse=False`` (tensor/fsdp-sharded models): each leaf is synchronized
  independently along its leading dimension (padded to X), so model-axis
  sharding on other dimensions is untouched by the exchange. Leaves smaller
  than one torus row fall back to ``psum`` (latency-bound anyway).

Both modes must run inside ``jax.shard_map`` where the grid axes are manual.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives
from repro.core.topology import TorusGrid


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "torus2d"           # psum | ring | hierarchical | torus2d
    lowering: str = "xla"               # xla | ring (explicit ppermute)
    comm_dtype: Any = jnp.bfloat16      # paper: fp16; TPU-native: bf16
    fp32_paths: tuple[str, ...] = ("batch_stats", "bn", "scale", "bias")
    fuse: bool = True
    mean: bool = True
    small_leaf_threshold: int = 2048    # below: plain psum (latency-bound)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def _world(grid: TorusGrid) -> int:
    from jax import lax
    size = 1
    for a in grid.axes:
        size *= lax.axis_size(a)
    return size


def _ring_multiple(grid: TorusGrid) -> int:
    """Leading-dim divisibility required by the strategies' scatter phases."""
    from jax import lax
    x = 1
    for a in grid.h_axes:
        x *= lax.axis_size(a)
    y = 1
    for a in grid.v_axes:
        y *= lax.axis_size(a)
    # torus2d ring lowering reduce-scatters the 1/X chunk again over Y
    return x * y


def sync_tree(grads, grid: TorusGrid, cfg: GradSyncConfig = GradSyncConfig()):
    """All-reduce (mean if cfg.mean) a gradient pytree over the DP grid."""
    if cfg.fuse:
        return _sync_fused(grads, grid, cfg)
    return _sync_per_leaf(grads, grid, cfg)


def _sync_fused(grads, grid: TorusGrid, cfg: GradSyncConfig):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(grads)
    if not leaves_p:
        return grads
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0

    comm_idx, fp32_idx = [], []
    for k, (path, leaf) in enumerate(leaves_p):
        ps = _path_str(path)
        if any(tag in ps for tag in cfg.fp32_paths) or leaf.dtype == jnp.float32 and leaf.ndim <= 1:
            fp32_idx.append(k)
        else:
            comm_idx.append(k)

    leaves = [l for _, l in leaves_p]
    out = [None] * len(leaves)

    for idx_group, dtype in ((comm_idx, cfg.comm_dtype), (fp32_idx, jnp.float32)):
        if not idx_group:
            continue
        flat = jnp.concatenate(
            [jnp.ravel(leaves[k]).astype(dtype) for k in idx_group])
        # pre-scale: keeps fp16/bf16 partial sums in range (paper exchanges
        # in half precision)
        flat = flat * jnp.asarray(scale, dtype)
        padded = _pad_to(flat, _ring_multiple(grid))
        reduced = collectives.all_reduce(padded, grid, cfg.strategy, cfg.lowering)
        reduced = reduced[: flat.shape[0]]
        off = 0
        for k in idx_group:
            size = leaves[k].size
            out[k] = reduced[off: off + size].reshape(leaves[k].shape).astype(leaves[k].dtype)
            off += size

    return jax.tree_util.tree_unflatten(treedef, out)


def _sync_per_leaf(grads, grid: TorusGrid, cfg: GradSyncConfig):
    from jax import lax
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0
    mult = _ring_multiple(grid)

    def sync_leaf(path, g):
        ps = _path_str(path)
        fp32 = any(tag in ps for tag in cfg.fp32_paths)
        dtype = jnp.float32 if fp32 else cfg.comm_dtype
        orig_dtype = g.dtype
        g = g.astype(dtype) * jnp.asarray(scale, dtype)
        if g.size < cfg.small_leaf_threshold or g.ndim == 0:
            g = lax.psum(g, grid.axes)
        else:
            n0 = g.shape[0]
            g = _pad_to(g, mult)
            g = collectives.all_reduce(g, grid, cfg.strategy, cfg.lowering)
            g = g[:n0]
        return g.astype(orig_dtype)

    return jax.tree_util.tree_map_with_path(sync_leaf, grads)
