"""Gradient synchronization over pytrees (the paper's technique as a
first-class framework feature).

Two execution modes, chosen by ``fuse``:

* ``fuse=True`` (paper-faithful, pure data-parallel): leaves are flattened
  into fused comm buffers (mixed-precision: comm-dtype group + fp32 group,
  §3.2 of the paper keeps BN statistics and LARS in fp32), padded to the
  ring size, exchanged with the selected strategy, and scattered back. This
  is what the paper's NCCL implementation does with bucket fusion, and it is
  only legal when the leaves are replicated over the model axis (ResNet /
  pure-DP configs).

  ``bucket_bytes`` controls *how many* fused buffers there are:

  - ``bucket_bytes=0`` (legacy): one buffer per precision group -- the
    exchange can only start after the full backward pass.
  - ``bucket_bytes>0``: each precision group is greedily partitioned into
    size-targeted buckets, **ordered in reverse-backprop order** (the pytree
    flatten order follows the forward pass, so the *last* leaves get their
    gradients *first* during backprop). One strategy-dispatch all-reduce is
    issued per bucket, earliest-ready bucket first, so XLA's latency-hiding
    scheduler can overlap each bucket's 2D-Torus exchange with the
    remaining backward compute. See docs/gradient_sync.md for the layout
    contract and ``collectives.bucketed_comm_cost_model`` for the
    latency-vs-overlap tradeoff model.

* ``fuse=False`` (tensor/fsdp-sharded models): each leaf is synchronized
  independently along its leading dimension (padded to X), so model-axis
  sharding on other dimensions is untouched by the exchange. Leaves smaller
  than one torus row fall back to ``psum`` (latency-bound anyway).

Both modes must run inside ``shard_map`` (see repro.compat) where the grid
axes are manual.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core import collectives
from repro.core.topology import TorusGrid


@dataclasses.dataclass(frozen=True)
class GradSyncConfig:
    strategy: str = "torus2d"           # psum | ring | hierarchical | torus2d
    lowering: str = "xla"               # xla | ring (explicit ppermute)
    comm_dtype: Any = jnp.bfloat16      # paper: fp16; TPU-native: bf16
    fp32_paths: tuple[str, ...] = ("batch_stats", "bn", "scale", "bias")
    fuse: bool = True
    mean: bool = True
    small_leaf_threshold: int = 2048    # below: plain psum (latency-bound)
    bucket_bytes: int = 0               # 0: single fused buffer per group;
                                        # >0: size-targeted comm buckets
    reverse_order: bool = True          # issue buckets reverse-backprop first


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path).lower()


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % multiple
    if rem == 0:
        return x
    pad = [(0, rem)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad)


def _world(grid: TorusGrid) -> int:
    size = 1
    for a in grid.axes:
        size *= compat.axis_size(a)
    return size


def _ring_multiple(grid: TorusGrid) -> int:
    """Leading-dim divisibility required by the strategies' scatter phases."""
    x = 1
    for a in grid.h_axes:
        x *= compat.axis_size(a)
    y = 1
    for a in grid.v_axes:
        y *= compat.axis_size(a)
    # torus2d ring lowering reduce-scatters the 1/X chunk again over Y
    return x * y


# ---------------------------------------------------------------------------
# Bucket partitioning (pure python; also used by benchmarks and the dry-run
# HLO audit, so it must stay trace-free)
# ---------------------------------------------------------------------------

def partition_buckets(leaf_bytes: Sequence[int], bucket_bytes: int) -> list[list[int]]:
    """Greedy partition of leaf indices into size-targeted buckets.

    Walks the leaves in the given order and closes a bucket as soon as its
    cumulative size reaches ``bucket_bytes`` (so each bucket is at least the
    target size except the last, and a single oversized leaf forms its own
    bucket). ``bucket_bytes <= 0`` returns one bucket with everything --
    the legacy fully-fused layout.
    """
    idx = list(range(len(leaf_bytes)))
    if bucket_bytes <= 0:
        return [idx] if idx else []
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0
    for i in idx:
        cur.append(i)
        cur_bytes += leaf_bytes[i]
        if cur_bytes >= bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
    if cur:
        buckets.append(cur)
    return buckets


def _itemsize(dtype) -> int:
    return np.dtype(dtype).itemsize


def _precision_groups(leaves_p, cfg: GradSyncConfig) -> list[tuple[list[int], Any]]:
    """Split leaf indices into (comm-dtype, fp32) groups, preserving order.

    The paper exchanges the bulk of the gradient in half precision but keeps
    BN statistics / scales / biases (and any fp32 vector leaf) in fp32;
    buckets never mix the two groups.
    """
    comm_idx, fp32_idx = [], []
    for k, (path, leaf) in enumerate(leaves_p):
        ps = _path_str(path)
        if any(tag in ps for tag in cfg.fp32_paths) or \
                leaf.dtype == jnp.float32 and leaf.ndim <= 1:
            fp32_idx.append(k)
        else:
            comm_idx.append(k)
    return [(comm_idx, cfg.comm_dtype), (fp32_idx, jnp.float32)]


def bucket_layout(grads, cfg: GradSyncConfig = GradSyncConfig()) -> list[dict]:
    """The bucket schedule ``sync_tree`` will issue, as metadata.

    Returns one dict per bucket in **issue order** with keys ``group``
    ("comm"|"fp32"), ``dtype``, ``nbytes``, ``num_leaves``, ``paths``.
    Works on concrete arrays or ShapeDtypeStructs; never traces. Used by the
    dry-run audit and the bucket-sweep benchmark to cross-check the HLO
    against the intended schedule.
    """
    leaves_p, _ = jax.tree_util.tree_flatten_with_path(grads)
    out = []
    for name, (idx_group, dtype) in zip(
            ("comm", "fp32"), _precision_groups(leaves_p, cfg)):
        if not idx_group:
            continue
        order = list(reversed(idx_group)) if cfg.reverse_order else list(idx_group)
        sizes = [leaves_p[k][1].size * _itemsize(dtype) for k in order]
        for bucket in partition_buckets(sizes, cfg.bucket_bytes):
            ks = [order[i] for i in bucket]
            out.append({
                "group": name,
                "dtype": np.dtype(dtype).name,
                "nbytes": sum(sizes[i] for i in bucket),
                "num_leaves": len(ks),
                "paths": [_path_str(leaves_p[k][0]) for k in ks],
            })
    return out


def sync_tree(grads, grid: TorusGrid, cfg: GradSyncConfig = GradSyncConfig()):
    """All-reduce (mean if cfg.mean) a gradient pytree over the DP grid."""
    if cfg.fuse:
        return _sync_fused(grads, grid, cfg)
    return _sync_per_leaf(grads, grid, cfg)


def record_bucket_metrics(grads_like, cfg: GradSyncConfig,
                          registry) -> list[dict]:
    """Publish the bucket schedule as gauges on a metrics registry
    (repro.obs.metrics; docs/observability.md has the name table).

    ``sync_tree`` itself runs inside jit/shard_map, so per-bucket numbers
    can't be recorded at execution time -- but the schedule is a pure
    host-side function of the gradient *structure* (``bucket_layout``),
    which the trainer knows the moment it resolves the sync config. Called
    with the params tree (same treedef as the grads) and the resolved
    config, this sets, for the fused path:

    * ``grad_sync/num_buckets``            -- buckets in issue order
    * ``grad_sync/total_nbytes``           -- bytes over all buckets
    * ``grad_sync/bucketNN/nbytes``        -- per-bucket comm payload
    * ``grad_sync/bucketNN/num_leaves``    -- leaves packed into bucket NN

    The multidevice obs smoke cross-checks the gauge count against
    ``hlo_stats.bucket_audit`` on the compiled step -- gauges describe the
    *intended* schedule, the audit the *compiled* one; they must agree.
    Returns the layout (issue order). No-ops (returns []) for the per-leaf
    ``fuse=False`` path, where there is no bucketing to describe.
    """
    if registry is None or not cfg.fuse:
        return []
    layout = bucket_layout(grads_like, cfg)
    registry.gauge("grad_sync/num_buckets").set(len(layout))
    registry.gauge("grad_sync/total_nbytes").set(
        sum(b["nbytes"] for b in layout))
    for i, b in enumerate(layout):
        registry.gauge(f"grad_sync/bucket{i:02d}/nbytes").set(b["nbytes"])
        registry.gauge(
            f"grad_sync/bucket{i:02d}/num_leaves").set(b["num_leaves"])
    return layout


# ---------------------------------------------------------------------------
# Graceful degradation: strategy fallback chain (docs/robustness.md)
# ---------------------------------------------------------------------------

#: Ordered degradation chain per strategy (2d_torus -> ... -> ring -> psum).
#: Later entries trade the paper's bandwidth-optimal schedule for
#: robustness: hierarchical (xla lowering) is all-reduce-only so it lowers
#: everywhere torus2d cannot, the flat ring is a single in-axis exchange
#: XLA may reroute around a dead link, and psum is the native all-reduce
#: that always lowers.
FALLBACK_CHAINS: dict[str, tuple[str, ...]] = {
    "torus2d": ("torus2d", "hierarchical", "ring", "psum"),
    "hierarchical": ("hierarchical", "ring", "psum"),
    "ring": ("ring", "psum"),
    "psum": ("psum",),
}


def fallback_chain(strategy: str) -> tuple[str, ...]:
    return FALLBACK_CHAINS.get(strategy, (strategy, "psum"))


def _strategy_viable(strategy: str, lowering: str, grid: TorusGrid, mesh,
                     manual_axes, down_axes=(), probe: bool = True):
    """(viable, reason). ``reason`` explains the rejection when not viable.

    Three checks, cheapest first:

    1. *Down axes*: torus2d / hierarchical decompose the reduction into
       per-axis phases that map onto physical link dimensions -- a down
       torus axis kills them. The flat strategies (ring with the xla
       lowering, psum) leave routing to the compiler/fabric and survive;
       the explicit ppermute ring lowering pins neighbor links, so it is
       rejected too.
    2. *Partial-manual shard_map*: on jaxlib < 0.5 the SPMD partitioner
       hard-aborts (uncatchable F-check) on scatter/gather/permute
       collectives when some mesh axes stay auto
       (``compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES``); only all-reduce-
       only strategies (psum, xla-lowered hierarchical) are safe there.
    3. *Trace probe*: a tiny ``jax.eval_shape`` of the strategy under the
       real mesh/grid catches anything else (missing primitives, bad axis
       factorization) without allocating or compiling. Only run when the
       shard_map is fully manual -- see (2) for why probing partial-manual
       combos is not safe.
    """
    down = set(down_axes) & set(grid.axes)
    if down:
        if strategy in ("torus2d", "hierarchical"):
            return False, (f"torus axis(es) {sorted(down)} down: per-axis "
                           "phase decomposition unavailable")
        if lowering == "ring":
            return False, (f"axis(es) {sorted(down)} down: explicit ppermute "
                           "ring pins dead neighbor links")

    manual = set(manual_axes)
    partial = bool(set(mesh.axis_names) - manual)
    if partial and not compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES:
        ar_only = strategy == "psum" or (strategy == "hierarchical"
                                         and lowering == "xla")
        if not ar_only:
            return False, ("partial-manual shard_map on this jaxlib only "
                           "lowers all-reduce collectives (jax >= 0.5 "
                           "needed for scatter/gather/permute)")

    if probe and not partial:
        try:
            mult = 1
            for a in grid.axes:
                mult *= int(mesh.shape[a])

            def _probe_sync(x):
                return collectives.all_reduce(x, grid, strategy, lowering)

            smapped = compat.shard_map(
                _probe_sync, mesh=mesh,
                in_specs=jax.sharding.PartitionSpec(),
                out_specs=jax.sharding.PartitionSpec(),
                axis_names=frozenset(manual), check_vma=False)
            jax.eval_shape(
                smapped, jax.ShapeDtypeStruct((mult,), jnp.float32))
        except Exception as e:  # noqa: BLE001 -- any trace failure degrades
            return False, f"trace probe failed: {type(e).__name__}: {e}"
    return True, ""


def resolve_sync_config(cfg: GradSyncConfig, grid: TorusGrid, mesh,
                        manual_axes, down_axes=(), probe: bool = True,
                        context: str = "startup"
                        ) -> tuple[GradSyncConfig, list[dict]]:
    """Walk ``cfg.strategy``'s fallback chain; return the first viable
    config plus the rejection/downgrade events (for history/logging).

    Never raises: psum terminates every chain and always lowers. A
    downgrade is an event, not an error -- the job keeps training
    (docs/robustness.md). ``context`` tags the events with *when* the
    resolution ran: ``"startup"`` (job launch) or ``"elastic"`` (mid-run
    re-resolution after a permanent failure, ``repro.train.elastic``).
    """
    events: list[dict] = []
    chain = fallback_chain(cfg.strategy)
    for strategy in chain:
        ok, reason = _strategy_viable(strategy, cfg.lowering, grid, mesh,
                                      manual_axes, down_axes, probe)
        if ok:
            if strategy != cfg.strategy:
                events.append({
                    "event": "grad_sync_downgrade",
                    "from": cfg.strategy, "to": strategy,
                    "context": context,
                })
            return dataclasses.replace(cfg, strategy=strategy), events
        events.append({"event": "grad_sync_strategy_rejected",
                       "strategy": strategy, "reason": reason,
                       "context": context})
    # unreachable in practice (psum has no rejection path), but never abort
    events.append({"event": "grad_sync_downgrade",
                   "from": cfg.strategy, "to": "psum", "context": context})
    return dataclasses.replace(cfg, strategy="psum"), events


def _sync_fused(grads, grid: TorusGrid, cfg: GradSyncConfig):
    leaves_p, treedef = jax.tree_util.tree_flatten_with_path(grads)
    if not leaves_p:
        return grads
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0
    mult = _ring_multiple(grid)

    leaves = [leaf for _, leaf in leaves_p]
    out: list = [None] * len(leaves)

    for idx_group, dtype in _precision_groups(leaves_p, cfg):
        if not idx_group:
            continue
        # reverse-backprop order: tree-flatten order tracks the forward
        # pass, so the last leaves' grads materialize first in backward --
        # their bucket is issued first and overlaps the rest of backprop.
        order = list(reversed(idx_group)) if cfg.reverse_order else list(idx_group)
        sizes = [leaves[k].size * _itemsize(dtype) for k in order]
        for bucket in partition_buckets(sizes, cfg.bucket_bytes):
            ks = [order[i] for i in bucket]
            flat = jnp.concatenate(
                [jnp.ravel(leaves[k]).astype(dtype) for k in ks])
            # pre-scale: keeps fp16/bf16 partial sums in range (paper
            # exchanges in half precision)
            flat = flat * jnp.asarray(scale, dtype)
            padded = _pad_to(flat, mult)
            reduced = collectives.all_reduce(padded, grid, cfg.strategy,
                                             cfg.lowering)
            reduced = reduced[: flat.shape[0]]
            off = 0
            for k in ks:
                size = leaves[k].size
                out[k] = reduced[off: off + size].reshape(
                    leaves[k].shape).astype(leaves[k].dtype)
                off += size

    return jax.tree_util.tree_unflatten(treedef, out)


def _sync_per_leaf(grads, grid: TorusGrid, cfg: GradSyncConfig):
    from jax import lax
    world = _world(grid)
    scale = 1.0 / world if cfg.mean else 1.0
    mult = _ring_multiple(grid)

    def sync_leaf(path, g):
        ps = _path_str(path)
        fp32 = any(tag in ps for tag in cfg.fp32_paths)
        dtype = jnp.float32 if fp32 else cfg.comm_dtype
        orig_dtype = g.dtype
        g = g.astype(dtype) * jnp.asarray(scale, dtype)
        if g.size < cfg.small_leaf_threshold or g.ndim == 0:
            g = lax.psum(g, grid.axes)
        else:
            n0 = g.shape[0]
            g = _pad_to(g, mult)
            g = collectives.all_reduce(g, grid, cfg.strategy, cfg.lowering)
            g = g[:n0]
        return g.astype(orig_dtype)

    return jax.tree_util.tree_map_with_path(sync_leaf, grads)
