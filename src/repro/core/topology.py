"""2D-Torus topology mapping (paper §2.2, Table 4).

The paper arranges N GPUs in an X (horizontal) x Y (vertical) logical grid
and decomposes the gradient all-reduce into
    reduce-scatter along X  ->  all-reduce along Y (1/X volume)  ->  all-gather along X.

On a JAX mesh the grid is expressed with *named axes*. Two situations:

1. The mesh already has >=2 data-parallel axes (e.g. ``("pod", "data")``):
   the torus maps directly -- X = the fast intra-pod axis, Y = the slow
   inter-pod axis, so the slow links carry 1/X of the bytes (the paper's
   core win, transplanted to TPU DCI).

2. A single data-parallel axis (e.g. ``data=16`` on one pod): we factorize
   it into an internal X*Y grid by *reshaping the mesh* before building the
   step function. ``factorize()`` picks X,Y the way the paper's Table 4
   does: as close to square as possible, with X >= Y (horizontal no smaller
   than vertical, matching e.g. 48x72, 64x64 in Table 4).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import numpy as np


def factorize(n: int) -> tuple[int, int]:
    """Split n into (Y, X), X >= Y, as square as possible (paper Table 4)."""
    if n < 1:
        raise ValueError(f"need n >= 1, got {n}")
    y = int(math.isqrt(n))
    while n % y != 0:
        y -= 1
    x = n // y
    # paper lists grids as (vertical, horizontal) with horizontal >= vertical
    if y > x:
        x, y = y, x
    return y, x


@dataclasses.dataclass(frozen=True)
class TorusGrid:
    """Named-axis description of the logical 2D torus.

    ``h_axes``: mesh axes forming the horizontal rings (reduce-scatter /
    all-gather phases). ``v_axes``: mesh axes forming the vertical rings
    (the middle all-reduce phase, which carries 1/X of the data).
    """

    h_axes: tuple[str, ...]
    v_axes: tuple[str, ...]

    @property
    def axes(self) -> tuple[str, ...]:
        return self.v_axes + self.h_axes

    def sizes(self, mesh: jax.sharding.Mesh | jax.sharding.AbstractMesh) -> tuple[int, int]:
        """(X, Y) sizes of the torus on a concrete mesh."""
        x = int(np.prod([mesh.shape[a] for a in self.h_axes])) if self.h_axes else 1
        y = int(np.prod([mesh.shape[a] for a in self.v_axes])) if self.v_axes else 1
        return x, y

    def steps(self, mesh) -> int:
        """Ring GPU-to-GPU steps: 2(X-1) horizontal + (vertical AR steps).

        Paper counts 2(X-1) for the horizontal phases; the vertical ring
        all-reduce adds 2(Y-1) steps on 1/X volume.
        """
        x, y = self.sizes(mesh)
        return 2 * (x - 1) + 2 * (y - 1)


def select_grid(dp_axes: Sequence[str]) -> TorusGrid:
    """Choose the torus orientation given the data-parallel mesh axes.

    With multiple DP axes the *last* axis (fastest-varying / intra-pod) is
    horizontal and the leading axes are vertical: the slow inter-pod links
    then carry the 1/X-reduced middle phase.
    """
    dp_axes = tuple(dp_axes)
    if not dp_axes:
        raise ValueError("at least one data-parallel axis required")
    if len(dp_axes) == 1:
        # degenerate: no second axis to split over -- callers who want a true
        # 2D torus on one axis should build a factorized mesh (see
        # launch/mesh.py make_factorized_mesh).
        return TorusGrid(h_axes=dp_axes, v_axes=())
    return TorusGrid(h_axes=(dp_axes[-1],), v_axes=tuple(dp_axes[:-1]))


def paper_table4_grid(n_gpus: int) -> tuple[int, int]:
    """The grid dimensions the paper used (Table 4), for the benchmark."""
    table = {1024: (32, 32), 2048: (32, 64), 2176: (34, 64), 3456: (48, 72), 4096: (64, 64)}
    if n_gpus in table:
        return table[n_gpus]
    return factorize(n_gpus)
