"""Learning-rate / momentum / batch-size schedules from the paper (§3.2).

Configuration A (from the TensorFlow TPU ResNet repo the paper cites):
  34-epoch linear LR warmup from 1e-5 to base LR 34.0, then polynomial
  (power-2) decay to 0 at epoch 90.

Configuration B (based on You et al. [10] + Smith & Le [16]):
  5-epoch linear warmup 0.2 -> 29, then
      lr(e) = 29 * (1 - e/90)^2          for e < 30
      lr(e) = 50 * (1 - e/90)^2          otherwise
  with momentum *recomputed per epoch from the SGD noise scale*. Smith & Le:
      noise_scale g ~= lr * N / (B * (1 - m))
  The paper anchors the noise scale at the reference run (B_ref = 32*1024,
  m_ref = 0.9) and solves for momentum at the live batch size B(e):
      g(e)   = lr(e) * N / (B_ref * (1 - m_ref))
      m(e)   = 1 - lr(e) * N / (B(e) * g(e))  =  1 - (1 - m_ref) * B_ref / B(e)
  (N, the dataset size, cancels.) NOTE: the paper's printed formula is
  corrupted by PDF extraction; this reconstruction follows [16] directly and
  reproduces the paper's anchor values (m = 0.9 at B = 32K).

Batch-size control (§2.1, Table 3): a *predetermined schedule* of per-worker
batch sizes over epoch ranges. Exposed as ``BatchStage`` list; the trainer
compiles one step function per stage (a batch-shape change is a new XLA
program -- same as the paper's NNL re-setup at stage boundaries).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

REF_BATCH = 32 * 1024     # paper's reference configuration (Table 3)
REF_MOMENTUM = 0.9
TOTAL_EPOCHS = 90.0


# ---------------------------------------------------------------------------
# Config A
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConfigA:
    base_lr: float = 34.0
    init_lr: float = 1e-5
    warmup_epochs: float = 34.0
    total_epochs: float = TOTAL_EPOCHS
    momentum: float = 0.9
    power: float = 2.0

    def lr(self, epoch):
        e = jnp.asarray(epoch, jnp.float32)
        warm = self.init_lr + (self.base_lr - self.init_lr) * e / self.warmup_epochs
        frac = jnp.clip((self.total_epochs - e) /
                        (self.total_epochs - self.warmup_epochs), 0.0, 1.0)
        decay = self.base_lr * frac ** self.power
        return jnp.where(e < self.warmup_epochs, warm, decay)

    def mom(self, epoch, batch_size=None):
        del batch_size
        return jnp.asarray(self.momentum, jnp.float32)


# ---------------------------------------------------------------------------
# Config B
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConfigB:
    warmup_epochs: float = 5.0
    warmup_init: float = 0.2
    base_lr_1: float = 29.0    # exact value from [10]
    base_lr_2: float = 50.0    # max suggested by [3]
    switch_epoch: float = 30.0
    total_epochs: float = TOTAL_EPOCHS
    ref_batch: int = REF_BATCH
    ref_momentum: float = REF_MOMENTUM

    def lr(self, epoch):
        e = jnp.asarray(epoch, jnp.float32)
        warm = self.warmup_init + (self.base_lr_1 - self.warmup_init) * e / self.warmup_epochs
        q = (1.0 - e / self.total_epochs) ** 2
        mid = self.base_lr_1 * q
        late = self.base_lr_2 * q
        out = jnp.where(e < self.switch_epoch, mid, late)
        return jnp.where(e < self.warmup_epochs, warm, out)

    def mom(self, epoch, batch_size):
        """Momentum from constant SGD noise scale (Smith & Le [16])."""
        del epoch  # m depends only on B under the constant-noise anchor
        b = jnp.asarray(batch_size, jnp.float32)
        m = 1.0 - (1.0 - self.ref_momentum) * self.ref_batch / b
        return jnp.clip(m, 0.0, 0.999)


SCHEDULES = {"A": ConfigA, "B": ConfigB}


def make(name: str, **kw):
    return SCHEDULES[name](**kw)


# ---------------------------------------------------------------------------
# Batch-size control (paper Table 3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatchStage:
    start_epoch: float
    end_epoch: float
    per_worker_batch: int

    def global_batch(self, n_workers: int) -> int:
        return self.per_worker_batch * n_workers


@dataclasses.dataclass(frozen=True)
class BatchSchedule:
    stages: tuple[BatchStage, ...]

    def __post_init__(self):
        es = list(self.stages)
        for a, b in zip(es, es[1:]):
            if a.end_epoch != b.start_epoch:
                raise ValueError(f"non-contiguous stages: {a} -> {b}")

    @property
    def total_epochs(self) -> float:
        return self.stages[-1].end_epoch

    def stage_at(self, epoch: float) -> BatchStage:
        for s in self.stages:
            if s.start_epoch <= epoch < s.end_epoch:
                return s
        return self.stages[-1]


def paper_schedule(exp: str) -> BatchSchedule:
    """The per-worker batch-size schedules of Table 3."""
    S = BatchStage
    table = {
        # Reference: flat 32/worker for 90 epochs
        "reference": (S(0, 90, 32),),
        # Exp. 1: 16/worker -> 32/worker at epoch 30 (34K -> 68K at 2176 GPUs)
        "exp1": (S(0, 30, 16), S(30, 90, 32)),
        # Exp. 2: 54K flat -- 16/w then 32/w at constant *global* size is the
        # paper's table quirk; we model global-size-preserving as two stages
        "exp2": (S(0, 30, 16), S(30, 90, 16)),
        # Exp. 3: 54K -> 64K
        "exp3": (S(0, 30, 16), S(30, 90, 19)),
        # Exp. 4: 34K -> 68K -> 85K -> 119K (4096 GPUs)
        "exp4": (S(0, 30, 16), S(30, 45, 16), S(45, 75, 32), S(75, 90, 32)),
    }
    return BatchSchedule(stages=table[exp])
