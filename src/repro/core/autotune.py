"""Bucket-size autotuning for the overlapped gradient exchange.

``GradSyncConfig.bucket_bytes`` controls the latency-vs-overlap tradeoff of
the bucketed gradient sync (docs/gradient_sync.md): too-large buckets leave
comm exposed after backprop ends, too-small buckets pay the per-exchange
alpha cost ``steps * latency`` once per bucket. This module picks the value
instead of a hand-set constant, in three layers:

1. :func:`analytic_knee_bytes` -- the closed-form serial-efficiency knee of
   ``collectives.comm_cost_model``: the bucket size where one bucket's wire
   time equals its latency term,

       knee = steps * latency * link_bw / wire_bytes_per_payload_byte

   (== ``steps * latency * link_bw / 2`` for the ring-family strategies
   whose wire volume is ~2x the payload -- the ROADMAP formula). Needs no
   knowledge of the model; this is the fallback when the gradient size is
   unknown.

2. :func:`recommend_bucket_bytes` -- numeric refinement: evaluate
   ``collectives.bucketed_comm_cost_model`` over a geometric candidate grid
   around the knee (plus the fused baseline ``0``) and take the candidate
   with the fewest exchanges whose ``exposed_seconds`` is within ``slack``
   of the optimum. Preferring fewer exchanges at equal exposure makes the
   pick robust to per-op overheads (kernel launch, scheduler) the
   alpha-beta model does not see.

3. :func:`refine_from_sweep` -- empirical refinement from
   ``launch/dryrun.py --sweep-bucket-bytes`` artifacts: rows carrying the
   compiled HLO's independent-exchange counts (``hlo_stats.bucket_audit``)
   and/or measured wall times next to the cost-model seconds. The sweep's
   measured optimum *bracket* (the candidates adjacent to the best row) is
   the acceptance band: an analytic pick outside it means the hardware
   model's constants are off for this arch/mesh.

The resolver entry point is ``grad_sync.resolve_sync_config``: a config
with ``bucket_bytes="auto"`` is resolved there (after the strategy fallback
chain ran, so the tuned value matches the strategy that will actually
execute -- elastic downgrades re-tune for the degraded schedule).
"""

from __future__ import annotations

import dataclasses

from repro.core import collectives


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Alpha-beta constants of one fabric + the overlap window.

    ``backward_seconds`` is the wall time of the backward pass the bucketed
    exchange overlaps with -- the only model-dependent constant. It only
    shifts *where* overlap saturates, not the knee itself, so a rough
    estimate (see ``configs/comm.py``) is fine.
    """

    link_bw: float = 50e9          # bytes/s per link (TPU ICI target)
    latency_s: float = 1e-6        # per ring-step latency (alpha)
    backward_seconds: float = 0.040
    name: str = "tpu-pod16x16"


#: The paper-target pod: 16x16 torus, 50 GB/s ICI, ~1 us step latency.
TPU_POD_HW = HardwareModel()

#: The hand-set constant this module replaces (docs/gradient_sync.md used
#: to recommend "4 MB is a good default"); kept as the comparison baseline.
LEGACY_DEFAULT_BUCKET_BYTES = 4 << 20


def analytic_knee_bytes(strategy: str, x: int, y: int,
                        hw: HardwareModel) -> int:
    """Closed-form knee: bucket size where wire time == latency term.

    Uses the strategy's own wire-volume ratio from ``comm_cost_model`` (a
    reference payload cancels out), so the formula specializes correctly
    for torus2d/hierarchical (``2(X-1)+2(Y-1)`` steps) vs the flat ring
    (``2(N-1)`` steps, hence a much larger knee).
    """
    ref = 1 << 20
    c = collectives.comm_cost_model(strategy, ref, x, y,
                                    hw.link_bw, hw.latency_s)
    wire_per_byte = c["wire_bytes"] / ref
    if wire_per_byte <= 0:       # degenerate 1x1 grid: no wire, no buckets
        return 0
    return max(1, int(c["steps"] * hw.latency_s * hw.link_bw
                      / wire_per_byte))


def candidate_bucket_bytes(knee: int, total_bytes: int | None = None,
                           span: int = 4) -> list[int]:
    """Geometric grid ``knee * 2**[-span..span]`` plus the fused baseline
    ``0``, clamped to ``total_bytes`` (a bucket larger than the gradient is
    the fused layout again)."""
    cands = {0}
    for k in range(-span, span + 1):
        b = int(knee * 2.0 ** k)
        if b <= 0:
            continue
        if total_bytes is not None and b >= total_bytes:
            continue
        cands.add(b)
    return sorted(cands)


def _evaluate(strategy: str, total_bytes: float, bucket_bytes: int,
              x: int, y: int, hw: HardwareModel) -> dict:
    m = collectives.bucketed_comm_cost_model(
        strategy, total_bytes, bucket_bytes, x, y,
        hw.link_bw, hw.latency_s, backward_seconds=hw.backward_seconds)
    return {"bucket_bytes": bucket_bytes,
            "num_buckets": m["num_buckets"],
            "exposed_seconds": m["exposed_seconds"],
            "serial_seconds": m["serial_seconds"]}


def recommend_bucket_bytes(strategy: str, x: int, y: int,
                           hw: HardwareModel,
                           total_bytes: float | None = None,
                           candidates: list[int] | None = None,
                           slack: float = 0.05) -> dict:
    """Pick ``bucket_bytes`` for one strategy/mesh/arch; returns the pick
    with the evidence attached.

    With ``total_bytes`` (the comm payload -- sum of ``bucket_layout``
    entry sizes) the pick minimizes the cost model's ``exposed_seconds``
    over ``candidates`` (default: a geometric grid around the analytic
    knee), tie-broken toward the fewest exchanges within ``slack`` relative
    exposure. Without it, the analytic knee alone is returned
    (``mode="analytic"``).
    """
    knee = analytic_knee_bytes(strategy, x, y, hw)
    base = {"strategy": strategy, "x": x, "y": y,
            "hw": dataclasses.asdict(hw),
            "analytic_knee_bytes": knee,
            "total_bytes": total_bytes}
    if total_bytes is None or total_bytes <= 0 or knee == 0:
        return {**base, "mode": "analytic", "bucket_bytes": knee,
                "candidates": []}

    cands = candidates if candidates is not None \
        else candidate_bucket_bytes(knee, int(total_bytes))
    if 0 not in cands:
        cands = [0] + list(cands)
    evaluated = [_evaluate(strategy, total_bytes, b, x, y, hw)
                 for b in sorted(set(int(b) for b in cands))]
    best = min(evaluated, key=lambda e: e["exposed_seconds"])
    feasible = [e for e in evaluated
                if e["exposed_seconds"]
                <= best["exposed_seconds"] * (1.0 + slack)]
    pick = min(feasible,
               key=lambda e: (e["num_buckets"], -e["bucket_bytes"]))
    fused = _evaluate(strategy, total_bytes, 0, x, y, hw)
    return {**base, "mode": "cost_model",
            "bucket_bytes": pick["bucket_bytes"],
            "num_buckets": pick["num_buckets"],
            "exposed_seconds": pick["exposed_seconds"],
            "best_exposed_seconds": best["exposed_seconds"],
            "fused_exposed_seconds": fused["exposed_seconds"],
            "candidates": evaluated}


# ---------------------------------------------------------------------------
# Empirical refinement from sweep artifacts
# ---------------------------------------------------------------------------

def sweep_bracket(rows: list[dict], key: str = "exposed_seconds") -> dict:
    """The measured optimum and its bracketing candidates.

    ``rows`` are sweep artifacts, one per swept ``bucket_bytes``, each
    carrying ``key``. Returns the best row's ``bucket_bytes`` plus the
    adjacent swept values ``low``/``high`` (``None`` = unbounded on that
    side): the band a cost-model pick must land in to be consistent with
    the sweep.
    """
    rows = sorted((r for r in rows if r.get(key) is not None),
                  key=lambda r: r["bucket_bytes"])
    if not rows:
        raise ValueError(f"no sweep rows carry {key!r}")
    i = min(range(len(rows)), key=lambda j: rows[j][key])
    return {
        "best_bucket_bytes": rows[i]["bucket_bytes"],
        "best_value": rows[i][key],
        "low": rows[i - 1]["bucket_bytes"] if i > 0 else None,
        "high": rows[i + 1]["bucket_bytes"] if i + 1 < len(rows) else None,
    }


def pick_within_bracket(bucket_bytes: int, bracket: dict) -> bool:
    """Is a pick inside the sweep's measured-optimum band (inclusive)?

    The fused sentinel ``0`` only matches a bracket that itself reaches
    down to the fused row.
    """
    lo, hi = bracket["low"], bracket["high"]
    if lo is not None and bucket_bytes < lo:
        return False
    if hi is not None and bucket_bytes > hi:
        return False
    return True


def refine_from_sweep(rows: list[dict], strategy: str, x: int, y: int,
                      hw: HardwareModel, total_bytes: float | None = None,
                      slack: float = 0.05) -> dict:
    """Combine sweep artifacts with the analytic model into a final pick.

    ``rows`` come from ``launch/dryrun.py --sweep-bucket-bytes`` (or
    ``benchmarks/allreduce.py``): each has ``bucket_bytes`` plus whichever
    evidence the sweep produced -- ``exposed_seconds`` (cost model),
    ``num_exchanges`` (HLO audit), ``us_per_call`` (measured). The pick is
    the sweep row with the fewest exchanges within ``slack`` of the best
    exposed time; the analytic recommendation rides along with an
    ``agrees`` flag (pick inside the sweep's optimum bracket), so a
    disagreement -- stale hardware constants -- is visible in the artifact
    instead of silently shipped.
    """
    usable = [r for r in rows if r.get("exposed_seconds") is not None]
    bracket = sweep_bracket(usable)
    best = min(usable, key=lambda r: r["exposed_seconds"])
    feasible = [r for r in usable
                if r["exposed_seconds"]
                <= best["exposed_seconds"] * (1.0 + slack)]
    pick = min(feasible,
               key=lambda r: (r.get("num_exchanges",
                                    r.get("num_buckets", 1 << 30)),
                              -r["bucket_bytes"]))
    analytic = recommend_bucket_bytes(strategy, x, y, hw,
                                      total_bytes=total_bytes)
    return {
        "mode": "sweep",
        "bucket_bytes": pick["bucket_bytes"],
        "exposed_seconds": pick["exposed_seconds"],
        "bracket": bracket,
        "analytic": analytic,
        "agrees": pick_within_bracket(analytic["bucket_bytes"], bracket),
    }
