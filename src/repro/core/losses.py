"""Label-smoothing cross-entropy (paper §2.1, Szegedy et al. [13]).

With smoothing factor alpha and K classes, the target distribution is
    q(k) = (1 - alpha) * onehot(k) + alpha / K
and the loss is KL-equivalent cross-entropy  -sum_k q(k) log p(k).

The fused Pallas kernel (``repro.kernels.ls_xent``) computes
log-softmax + smoothed NLL in one VMEM pass -- the 256K-vocab archs make
this memory-bound; ``use_kernel`` routes through it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def label_smoothing_xent(logits: jax.Array, labels: jax.Array,
                         smoothing: float = 0.1, use_kernel: bool = False,
                         where=None) -> jax.Array:
    """Mean smoothed cross-entropy.

    logits: (..., K) float; labels: (...) int. ``where``: optional bool mask
    over the batch positions (padding).
    """
    if use_kernel:
        from repro.kernels import ops as kops
        per = kops.ls_xent(logits, labels, smoothing=smoothing)
    else:
        per = ls_xent_ref(logits, labels, smoothing)
    if where is not None:
        per = jnp.where(where, per, 0.0)
        return per.sum() / jnp.maximum(where.sum(), 1)
    return per.mean()


def ls_xent_ref(logits: jax.Array, labels: jax.Array, smoothing: float) -> jax.Array:
    """Per-example smoothed NLL, pure jnp (oracle for the Pallas kernel)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mean_logp = logp.mean(axis=-1)
    return (1.0 - smoothing) * nll - smoothing * mean_logp


def softmax_xent(logits: jax.Array, labels: jax.Array, where=None) -> jax.Array:
    """Plain CE (the no-LS ablation)."""
    return label_smoothing_xent(logits, labels, smoothing=0.0, where=where)


def top1_accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (logits.argmax(-1) == labels).mean()
