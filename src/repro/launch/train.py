"""Training launcher: ``--arch <id>`` + paper recipe on the current mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 20 [--sync torus2d] [--schedule B] [--batch-stages 2,4]

On this CPU container ``--smoke`` (reduced config, 8 host devices) is the
only runnable mode; on a real pod the same entrypoint builds the production
mesh and the full config. The paper's recipe -- 2D-torus gradient sync,
LARS, label smoothing, batch-size control -- is the default.
"""

import argparse
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticTokens
from repro.models import transformer as T
from repro.train.state import TrainState
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(registry.ARCH_IDS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--sync", default="torus2d",
                    choices=["psum", "ring", "hierarchical", "torus2d"])
    ap.add_argument("--schedule", default="B", choices=["A", "B"])
    ap.add_argument("--label-smoothing", type=float, default=0.1)
    ap.add_argument("--batch-stages", default="2,4",
                    help="comma per-worker batch sizes, staged equally")
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()

    if args.smoke:
        cfg = registry.get_smoke(args.arch)
        mesh = jax.make_mesh((2, 4), ("dy", "dx"))
        dp_axes = ("dy", "dx")
    else:
        from repro.launch.mesh import dp_axes_of, make_production_mesh
        cfg = registry.get(args.arch)
        mesh = make_production_mesh()
        dp_axes = dp_axes_of(mesh)
    n_workers = int(jax.device_count() if args.smoke else 256)

    data = SyntheticTokens(vocab=cfg.vocab)

    def loss_fn(params, batch, dp):
        tokens, labels = batch
        logits, aux = T.forward(params, tokens, cfg)
        return losses.label_smoothing_xent(
            logits, labels, args.label_smoothing), aux

    sizes = [int(s) for s in args.batch_stages.split(",")]
    span = 1.0
    stages = tuple(
        BatchStage(i * span, (i + 1) * span, s) for i, s in enumerate(sizes))
    plan = build_plan(BatchSchedule(stages), dataset_size=n_workers * 512,
                      n_workers=n_workers, max_steps=args.steps)

    trainer = Trainer(
        mesh=mesh, dp_axes=dp_axes, loss_fn=loss_fn,
        cfg=TrainerConfig(
            schedule=args.schedule, label_smoothing=args.label_smoothing,
            grad_sync=GradSyncConfig(strategy=args.sync, fuse=False,
                                     comm_dtype=jnp.bfloat16),
            log_every=5),
        plan=plan, data_fn=lambda i, gb: data.batch(i, gb, args.seq),
        checkpoint_dir=args.checkpoint_dir)

    print(f"training {cfg.name} ({cfg.num_params() / 1e6:.1f}M params) "
          f"with sync={args.sync} schedule={args.schedule}")
    state = TrainState.create(T.init(jax.random.key(0), cfg))
    state, history = trainer.run(state)
    print(f"done: loss {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
