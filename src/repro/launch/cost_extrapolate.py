"""Scan-aware cost extrapolation for the dry-run artifacts.

``compiled.cost_analysis()`` (and the HLO text) count a ``lax.scan`` body
ONCE, so per-layer costs of the scanned block stack are undercounted by a
factor of n_blocks. This pass recovers the true per-step cost with a
two-point linear fit:

    lower the same step with n_blocks = 1 and = 2
        (and chunking disabled -- q_chunk=0, ssm_chunk=seq -- so no *inner*
         while loop hides cost either)
    body  = cost(2) - cost(1)
    total = cost(1) + body * (n_blocks - 1)

and merges {flops, bytes_accessed, collective bytes (per dtype)} back into
each experiments/dryrun/*.json as the ``cost_true`` field used by
benchmarks/roofline.py.

    PYTHONPATH=src python -m repro.launch.cost_extrapolate [--only <arch>]
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import glob
import json


from repro.configs.shapes import SHAPES
from repro import compat
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T


def _cost_cfg(cfg: T.ArchConfig, k_blocks: int, seq_len: int) -> T.ArchConfig:
    n_layers = cfg.n_prefix + k_blocks * len(cfg.pattern)
    return dataclasses.replace(cfg, n_layers=n_layers, scan_blocks=False,
                               q_chunk_unroll=True, ssm_unroll=True)


def _extract(compiled):
    ca = compat.cost_analysis(compiled)
    coll = hlo_stats.collective_stats(compiled.as_text())
    return {
        "flops": ca.get("flops", 0.0),
        "bytes_accessed": ca.get("bytes accessed", 0.0),
        "coll_total": float(coll["total_bytes"]),
        "coll_f32": float(coll["by_dtype"].get("f32", 0)),
        "coll_wire": float(coll["total_wire_bytes"]),
        "coll_wire_f32": float(coll["wire_by_dtype"].get("f32", 0)),
    }


def extrapolate(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    from repro.launch import dryrun as D
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    base_cfg = D.arch_for(arch_id, shape)

    costs = {}
    for k in (1, 2):
        cfg = _cost_cfg(base_cfg, k, shape.seq_len)
        if shape.step == "train":
            fn, args = D.build_train(arch_id, cfg, shape, mesh)
        elif shape.step == "prefill":
            fn, args = D.build_prefill(arch_id, cfg, shape, mesh)
        else:
            fn, args = D.build_decode(arch_id, cfg, shape, mesh)
        costs[k] = _extract(fn.lower(*args).compile())

    nb = base_cfg.n_blocks
    out = {}
    for key in costs[1]:
        body = costs[2][key] - costs[1][key]
        out[key] = costs[1][key] + body * (nb - 1)
        out[f"{key}_body"] = body
    out["n_blocks"] = nb
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="arch substring filter")
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if args.only and args.only not in rec["arch"]:
            continue
        if "cost_true" in rec and not args.force:
            print(f"[skip] {os.path.basename(path)}")
            continue
        try:
            ct = extrapolate(rec["arch"], rec["shape"],
                             rec["mesh"] == "pod2x16x16")
            rec["cost_true"] = ct
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"[ok] {os.path.basename(path)} "
                  f"flops {rec['cost']['flops']:.2e} -> {ct['flops']:.2e}")
        except Exception as e:  # noqa: BLE001
            print(f"[fail] {os.path.basename(path)}: {e!r}")


if __name__ == "__main__":
    main()
