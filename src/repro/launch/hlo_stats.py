"""Parse collective traffic and op stats out of post-SPMD HLO text.

``collective_bytes`` is not in ``compiled.cost_analysis()``; we recover it
from the optimized HLO: every all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute op's *output* bytes are summed per op kind
(output bytes == bytes received per device, the roofline-relevant number;
for reduce-scatter the on-wire volume per device is (n-1)/n of the input --
we report output bytes and note the convention in EXPERIMENTS.md).
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %all-gather.3 = bf16[16,4096]{1,0} all-gather(...)
#        ROOT %r = (f32[8]{0}, f32[8]{0}) tuple(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^ ]*\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^\n]*)", re.M)

_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(rest: str) -> int:
    m = _GROUPS_RE.search(rest)
    if m:
        return m.group(1).count(",") + 1
    m = _GROUPS_IOTA_RE.search(rest)
    if m:                      # [n_groups, group_size]<=[...]
        return int(m.group(2))
    return 2


def _wire_bytes(kind: str, out_bytes: int, n: int) -> float:
    """Bytes per device on the wire for a ring realization of the op.

    all-reduce: 2*(n-1)/n * size; all-gather: (n-1)/n * output;
    reduce-scatter: (n-1) * output (input is n*output);
    all-to-all: (n-1)/n * size; collective-permute: full size.
    """
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * out_bytes
    if kind == "all-gather":
        return (n - 1) / n * out_bytes
    if kind == "reduce-scatter":
        return float((n - 1) * out_bytes)
    if kind == "all-to-all":
        return (n - 1) / n * out_bytes
    return float(out_bytes)    # collective-permute


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype, 4)
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def collective_stats(hlo_text: str) -> dict:
    """-> {op_kind: {"count", "bytes"}, "by_dtype": {dt: bytes},
    "total_bytes", "total_count"}. Per-dtype split lets the roofline apply
    the f32->bf16 exchange correction for the CPU-lowered gradient sync."""
    out: dict = {k: {"count": 0, "bytes": 0, "wire_bytes": 0.0}
                 for k in _COLLECTIVES}
    by_dtype: dict[str, int] = defaultdict(int)
    wire_by_dtype: dict[str, float] = defaultdict(float)
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind, rest = m.groups()
        nb = _nbytes(dtype, dims)
        wb = _wire_bytes(kind, nb, _group_size(rest))
        out[kind]["count"] += 1
        out[kind]["bytes"] += nb
        out[kind]["wire_bytes"] += wb
        by_dtype[dtype] += nb
        wire_by_dtype[dtype] += wb
    out["by_dtype"] = dict(by_dtype)
    out["wire_by_dtype"] = dict(wire_by_dtype)
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict) and "bytes" in v)
    out["total_wire_bytes"] = sum(v["wire_bytes"] for k, v in out.items()
                                  if isinstance(v, dict) and "wire_bytes" in v)
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict) and "count" in v)
    return out


def collective_schedule(hlo_text: str) -> list[dict]:
    """Every collective op in program order: {kind, dtype, nbytes, group_size}.

    Unlike :func:`collective_stats` (aggregates), this keeps the per-op
    sequence so a bucketed gradient exchange can be audited op by op.
    """
    out = []
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, kind, rest = m.groups()
        out.append({"kind": kind, "dtype": dtype,
                    "nbytes": _nbytes(dtype, dims),
                    "group_size": _group_size(rest)})
    return out


def bucket_audit(hlo_text: str, min_bytes: int = 0) -> dict:
    """Audit the gradient-sync bucket schedule in compiled HLO.

    Counts *independent reduction exchanges*: for the torus2d/ring/
    hierarchical xla lowerings each bucket compiles to its own
    reduce-scatter (+ all-reduce + all-gather) chain, and for psum to its
    own all-reduce -- so ``num_exchanges = max(#reduce-scatter,
    #all-reduce)`` over ops of at least ``min_bytes`` (filter out tiny
    metric/loss psums). A fully fused sync shows 1; a multi-bucket sync
    shows one per bucket, which is the structural proof that XLA *can*
    overlap each exchange with remaining backward compute.

    Ops below the floor are not silently hidden: the ``dropped`` entry
    reports their count/bytes (and per-kind split) so an audit whose floor
    swallowed real gradient buckets -- e.g. the sub-KiB fp32 group of a
    small model -- is visible in the artifact. Callers should derive
    ``min_bytes`` from the resolved bucket schedule (see
    ``launch.dryrun``), not hardcode it.
    """
    all_ops = collective_schedule(hlo_text)
    sched = [op for op in all_ops if op["nbytes"] >= min_bytes]
    dropped_ops = [op for op in all_ops if op["nbytes"] < min_bytes]
    by_kind: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    for op in sched:
        by_kind[op["kind"]]["count"] += 1
        by_kind[op["kind"]]["bytes"] += op["nbytes"]
    dropped_by_kind: dict[str, dict] = defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for op in dropped_ops:
        dropped_by_kind[op["kind"]]["count"] += 1
        dropped_by_kind[op["kind"]]["bytes"] += op["nbytes"]
    n_rs = by_kind["reduce-scatter"]["count"]
    n_ar = by_kind["all-reduce"]["count"]
    return {
        "num_exchanges": max(n_rs, n_ar),
        "by_kind": dict(by_kind),
        "ops": sched,
        "dropped": {
            "min_bytes": min_bytes,
            "count": len(dropped_ops),
            "bytes": sum(op["nbytes"] for op in dropped_ops),
            "by_kind": dict(dropped_by_kind),
        },
    }


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Rough instruction histogram (op name -> count) for schedule audits."""
    counts: dict[str, int] = defaultdict(int)
    for m in re.finditer(r"=\s*(?:\()?[a-z0-9]+\[[^\]]*\][^ ]*\s*([a-z][\w-]*)\(",
                         hlo_text):
        counts[m.group(1)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
