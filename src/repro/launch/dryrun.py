"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination against the production mesh and record memory / FLOPs /
collective-traffic analysis. No arrays are ever allocated: inputs are
ShapeDtypeStructs; this proves the distribution config is coherent.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""

# The production mesh needs 512 placeholder devices; jax locks the device
# count at first init, so this MUST precede every other import. The chaos
# *training* smoke (--chaos-train) actually executes steps, so it uses the
# 8-device test mesh instead -- 512 simulated devices would make every
# step interminable.
import os
import sys
_N_DEVICES = 8 if "--chaos-train" in sys.argv else 512
os.environ["XLA_FLAGS"] = (
    f"--xla_force_host_platform_device_count={_N_DEVICES} "
    + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs import comm as comm_cfg
from repro.configs import registry
from repro.configs.shapes import SHAPES, ShapeConfig, long_context_variant
from repro.core import autotune, collectives
from repro.core import grad_sync as grad_sync_lib
from repro.core import losses
from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core import lars as lars_lib
from repro.core.topology import select_grid
from repro.launch import hlo_stats
from repro import obs
from repro.testing.chaos import FaultPlan
from repro.launch.mesh import (cache_pspecs, dp_axes_of, make_production_mesh,
                               param_pspecs, with_shardings)
from repro.models import transformer as T

# archs whose params cannot be data-replicated even at TP=16: jit-auto
# fsdp sharding (ZeRO-style; DESIGN.md §3). The rest use the paper's
# explicit shard_map gradient sync.
FSDP_ARCHS = {"llama-3.2-vision-90b", "kimi-k2-1t-a32b", "llama3-405b",
              "gemma2-27b"}


# Strategy degradation (old-jaxlib partial-manual lowering limits, injected
# torus-link faults) is handled by the shared fallback chain in
# repro.core.grad_sync.resolve_sync_config; build_train records the
# resolved strategy + downgrade events and run_one writes them to the JSON.


def _bucket_bytes_arg(s: str):
    """--bucket-bytes parser: an int, or the literal "auto" sentinel."""
    return s if s == grad_sync_lib.AUTO else int(s)


def sds(shape, dtype, mesh=None, spec=None):
    sharding = NamedSharding(mesh, spec) if mesh is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_spec(batch: int, mesh) -> P:
    """Shard the batch over DP axes only when divisible (long_500k has B=1)."""
    dp = dp_axes_of(mesh)
    import numpy as np
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    return P(dp) if batch % dp_size == 0 else P()


def arch_for(arch_id: str, shape: ShapeConfig) -> T.ArchConfig:
    cfg = registry.get(arch_id)
    if shape.name == "long_500k":
        cfg = long_context_variant(cfg)
    if shape.step == "train":
        cfg = dataclasses.replace(cfg, remat=True)
    return cfg


def _vision_sds(cfg, batch, mesh, dp):
    if not cfg.vision_tokens:
        return None
    return sds((batch, cfg.vision_tokens, cfg.cross_kv_dim), jnp.bfloat16,
               mesh, batch_spec(batch, mesh))


# ---------------------------------------------------------------------------
# step builders: return (jitted_fn, args_tree_of_SDS)
# ---------------------------------------------------------------------------

def build_train(arch_id, cfg, shape, mesh, sync_strategy="torus2d",
                fuse=None, bucket_bytes=0, down_axes=()):
    sync_info = {"effective": None, "events": [], "config": None}
    dp = dp_axes_of(mesh)
    fsdp = arch_id in FSDP_ARCHS
    params_sds = jax.eval_shape(lambda: T.init(jax.random.key(0), cfg))
    pspecs = param_pspecs(params_sds, fsdp=fsdp, mesh=mesh)
    params_sds = with_shardings(params_sds, mesh, pspecs)
    mom_sds = params_sds   # momentum mirrors params
    tokens = sds((shape.global_batch, shape.seq_len), jnp.int32, mesh, P(dp))
    labels = tokens
    vision = _vision_sds(cfg, shape.global_batch, mesh, dp)

    def loss_of(params, tokens, labels, vision):
        logits, aux = T.forward(params, tokens, cfg, vision=vision)
        return losses.label_smoothing_xent(logits, labels, 0.1) + 0.01 * aux

    if fsdp:
        # jit-auto data+tensor sharding: XLA derives the ZeRO collective
        # schedule from in/out shardings (beyond-paper regime, DESIGN.md §3)
        def step(params, mom, tokens, labels, vision):
            loss, grads = jax.value_and_grad(loss_of)(params, tokens, labels,
                                                      vision)
            new_p, new_m = lars_lib.update(
                params, grads, {"momentum": mom}, lr=1.0, momentum=0.9)
            return loss, new_p, new_m["momentum"]

        out_sh = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs)
        fn = jax.jit(step, out_shardings=(NamedSharding(mesh, P()),
                                          out_sh, out_sh))
    else:
        # the paper's technique: manual DP grad sync under shard_map.
        # fuse=False: leaves are model-sharded (TP), raveling them would
        # force gathers. comm_dtype: the TPU target exchanges gradients in
        # bf16 (paper: fp16), but XLA's *CPU* AllReducePromotion pass
        # crashes on bf16 partial all-reduces over model-sharded operands
        # ("Invalid binary instruction opcode copy"); on CPU we lower the
        # exchange in f32 and the roofline applies the documented /2
        # correction for gradient traffic (EXPERIMENTS.md §Roofline).
        comm_dtype = (jnp.bfloat16 if jax.default_backend() == "tpu"
                      else jnp.float32)
        grid = select_grid(dp)
        # bucket_bytes shapes both paths: fused comm buckets (pure DP) and
        # the grouped small-leaf psums of the per-leaf (TP) path. "auto"
        # is resolved below against this mesh's fabric constants.
        gcfg = GradSyncConfig(strategy=sync_strategy,
                              fuse=False if fuse is None else fuse,
                              comm_dtype=comm_dtype,
                              bucket_bytes=bucket_bytes)
        # graceful degradation: partial-manual shard_map (model axis auto)
        # limits old jaxlib to all-reduce-only schedules, and injected
        # torus-link faults (--inject-faults) kill the per-axis phase
        # decompositions -- downgrade along the chain and record it
        # rather than abort the audit (docs/robustness.md).
        gcfg, sync_events = grad_sync_lib.resolve_sync_config(
            gcfg, grid, mesh, dp, down_axes=down_axes, probe=False,
            params_like=params_sds, hw=comm_cfg.hw_for_mesh(mesh))
        layout = grad_sync_lib.bucket_layout(params_sds, gcfg)
        sync_info = {"effective": gcfg.strategy, "events": sync_events,
                     "config": {k: (v if isinstance(
                         v, (int, float, bool, str, type(None))) else str(v))
                         for k, v in dataclasses.asdict(gcfg).items()},
                     "expected_exchanges": len(layout),
                     "min_exchange_bytes": (min(b["nbytes"] for b in layout)
                                            if layout else None)}

        def step(params, mom, tokens, labels, vision):
            loss, grads = jax.value_and_grad(loss_of)(params, tokens, labels,
                                                      vision)
            grads = sync_tree(grads, grid, gcfg)
            new_p, new_m = lars_lib.update(
                params, grads, {"momentum": mom}, lr=1.0, momentum=0.9)
            return jax.lax.pmean(loss, dp), new_p, new_m["momentum"]

        smapped = compat.shard_map(
            step, mesh=mesh,
            in_specs=(P(), P(), P(dp), P(dp),
                      P(dp) if vision is not None else P()),
            out_specs=(P(), P(), P()),
            axis_names=frozenset(dp), check_vma=False)
        fn = jax.jit(smapped)

    # vision=None is an empty pytree: jit/shard_map treat it transparently
    return fn, (params_sds, mom_sds, tokens, labels, vision), sync_info


def build_prefill(arch_id, cfg, shape, mesh):
    dp = dp_axes_of(mesh)
    fsdp = arch_id in FSDP_ARCHS
    params_sds = jax.eval_shape(lambda: T.init(jax.random.key(0), cfg))
    params_sds = with_shardings(params_sds, mesh,
                                param_pspecs(params_sds, fsdp=fsdp, mesh=mesh))
    tokens = sds((shape.global_batch, shape.seq_len), jnp.int32, mesh,
                 batch_spec(shape.global_batch, mesh))
    vision = _vision_sds(cfg, shape.global_batch, mesh, dp)

    def fn(params, tokens, vision):
        return T.prefill(params, tokens, cfg, vision=vision)

    return jax.jit(fn, static_argnames=()), (params_sds, tokens, vision)


def build_decode(arch_id, cfg, shape, mesh):
    dp = dp_axes_of(mesh)
    fsdp = arch_id in FSDP_ARCHS
    params_sds = jax.eval_shape(lambda: T.init(jax.random.key(0), cfg))
    params_sds = with_shardings(params_sds, mesh,
                                param_pspecs(params_sds, fsdp=fsdp, mesh=mesh))
    B = shape.global_batch
    cache_sds = jax.eval_shape(
        lambda: T.init_cache(cfg, B, shape.seq_len))
    cache_sds = with_shardings(cache_sds, mesh,
                               cache_pspecs(cache_sds, dp, mesh))
    token = sds((B, 1), jnp.int32, mesh, batch_spec(B, mesh))
    index = sds((), jnp.int32, mesh, P())

    def fn(params, token, cache, index):
        return T.decode_step(params, token, cache, index, cfg)

    return jax.jit(fn), (params_sds, token, cache_sds, index)


def _audit_floor(sync_info: dict) -> int:
    """min_bytes floor for the HLO bucket audit, derived from the resolved
    schedule instead of a hardcoded constant: low enough to keep the
    smallest intended exchange (a sub-KiB fp32 group of a small model
    would otherwise vanish from the audit), high enough (>= 16 B) to drop
    scalar loss/metric psums. FSDP runs have no manual schedule and keep
    the historical 1 KiB floor."""
    smallest = sync_info.get("min_exchange_bytes")
    if smallest is None:
        return 1024
    return max(16, min(1024, int(smallest)))


def _audit_summary(hlo: str, sync_info: dict) -> dict:
    audit = hlo_stats.bucket_audit(hlo, min_bytes=_audit_floor(sync_info))
    return {"num_exchanges": audit["num_exchanges"],
            "min_bytes": audit["dropped"]["min_bytes"],
            "by_kind": audit["by_kind"],
            "dropped": {k: audit["dropped"][k]
                        for k in ("count", "bytes", "by_kind")}}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def run_one(arch_id: str, shape_name: str, multi_pod: bool,
            sync_strategy: str = "torus2d", out_dir: str = "experiments/dryrun",
            save: bool = True, quiet: bool = False,
            bucket_bytes: int | str = 0,
            fault_plan: FaultPlan | None = None) -> dict:
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = arch_for(arch_id, shape)
    down_axes = tuple(fault_plan.down_axes) if fault_plan is not None else ()

    sync_info = {"effective": None, "events": [], "config": None}
    t0 = time.time()
    if shape.step == "train":
        if arch_id not in FSDP_ARCHS and \
                not compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES:
            # jaxlib < 0.5's SPMD partitioner hard-aborts (F-level check,
            # not catchable) on the transformer fwd/bwd inside a
            # partial-manual shard_map; fail with a python error instead.
            raise RuntimeError(
                f"{arch_id} train dry-run needs partial-manual shard_map "
                "support (jax >= 0.5); this jaxlib's SPMD partitioner "
                "aborts the process on it. FSDP archs and prefill/decode "
                "shapes are unaffected (see repro/compat.py).")
        fn, args, sync_info = build_train(arch_id, cfg, shape, mesh,
                                          sync_strategy,
                                          bucket_bytes=bucket_bytes,
                                          down_axes=down_axes)
    elif shape.step == "prefill":
        fn, args = build_prefill(arch_id, cfg, shape, mesh)
    else:
        fn, args = build_decode(arch_id, cfg, shape, mesh)

    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = hlo_stats.collective_stats(hlo)

    n_chips = mesh.devices.size
    # artifact provenance (docs/observability.md): a fresh run_id names
    # this invocation; the fingerprint hashes the *resolved* distribution
    # config (post-downgrade strategy included) so artifacts from
    # different runs of the same config join on it.
    mesh_summary = {a: int(mesh.shape[a]) for a in mesh.axis_names}
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "run_id": obs.new_run_id(),
        "config_fingerprint": obs.fingerprint({
            "arch": arch_id, "shape": shape_name, "mesh": mesh_summary,
            "grad_sync": sync_info["config"],
            "fsdp": arch_id in FSDP_ARCHS}),
        "mesh_summary": mesh_summary,
        "grad_sync_config": sync_info["config"],
        "step": shape.step, "chips": int(n_chips),
        "fsdp": arch_id in FSDP_ARCHS,
        "sync_strategy": sync_strategy if shape.step == "train" else None,
        "sync_strategy_effective": sync_info["effective"],
        "sync_downgrade_events": sync_info["events"] or None,
        "fault_injection": ({"down_axes": list(down_axes)}
                            if down_axes else None),
        "bucket_bytes": bucket_bytes if shape.step == "train" else None,
        "bucket_bytes_resolved": ((sync_info["config"] or {}).get(
            "bucket_bytes") if shape.step == "train" else None),
        "expected_exchanges": sync_info.get("expected_exchanges"),
        "bucket_audit": (_audit_summary(hlo, sync_info)
                         if shape.step == "train" else None),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
        },
        "collectives": coll,
        "model_params": cfg.num_params(),
        "active_params": cfg.active_params(),
        "grad_comm_dtype": ("f32-on-cpu(bf16-on-tpu)"
                            if shape.step == "train" else None),
    }
    if save:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    if not quiet:
        mb = (result["memory"]["temp_bytes"] or 0) / n_chips / 2**30
        print(f"[OK] {arch_id:22s} {shape_name:12s} {mesh_name:10s} "
              f"lower {t_lower:5.1f}s compile {t_compile:6.1f}s "
              f"flops {cost.get('flops', 0):.3e} "
              f"coll {coll['total_bytes'] / 2**30:.2f}GiB "
              f"temp/chip {mb:.2f}GiB")
    return result


def sweep_bucket_bytes(arch_id: str, multi_pod: bool = False,
                       sync_strategy: str = "torus2d",
                       out_dir: str = "experiments/dryrun",
                       save: bool = True, smoke_arch: bool = False,
                       candidates: list[int] | None = None,
                       max_hlo_buckets: int = 256,
                       slack: float = 0.05) -> dict:
    """Empirical bucket-size sweep: compile the *sync-only* program (fused
    bucketed ``sync_tree`` under a fully-manual shard_map -- the
    partial-manual train step aborts on this jaxlib, see repro/compat.py)
    at production scale for each candidate ``bucket_bytes``, audit the
    compiled HLO's independent exchanges, and pair every row with the
    alpha-beta cost model. The autotuner's pick
    (``autotune.recommend_bucket_bytes`` over the union of the sweep's
    candidates) is then gated against the sweep:

    * its cost-model ``exposed_seconds`` is within 10% of the sweep's best,
    * it strictly beats both ``bucket_bytes=0`` (fused) and the legacy
      hand-set 4 MiB constant,
    * it lands inside the sweep's measured-optimum bracket.

    Writes ``bucket_sweep__<arch>__<mesh>.json``; raises ``SystemExit``
    when a gate fails -- the CI ``bucket-sweep`` job runs exactly this on
    the smoke config. Candidates whose schedule exceeds ``max_hlo_buckets``
    skip compilation (cost-model row only, with the skip recorded): a
    full-size arch near the knee can need thousands of buckets, which the
    sweep reports rather than silently compiles for an hour.
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cfg = (registry.get_smoke(arch_id) if smoke_arch
           else registry.get(arch_id))
    dp = dp_axes_of(mesh)
    grid = select_grid(dp)
    x, y = grid.sizes(mesh)
    comm_dtype = (jnp.bfloat16 if jax.default_backend() == "tpu"
                  else jnp.float32)
    hw = comm_cfg.hw_for_mesh(mesh)

    # fully-manual over ALL mesh axes (model axis included) -> grads must
    # be replicated; the fused pure-DP path is exactly that regime.
    gcfg0 = GradSyncConfig(strategy=sync_strategy, fuse=True,
                           comm_dtype=comm_dtype, bucket_bytes=0)
    gcfg0, resolve_events = grad_sync_lib.resolve_sync_config(
        gcfg0, grid, mesh, mesh.axis_names, probe=False)
    strategy = gcfg0.strategy

    params_sds = jax.eval_shape(lambda: T.init(jax.random.key(0), cfg))
    params_sds = jax.tree.map(
        lambda s: sds(s.shape, s.dtype, mesh, P()), params_sds)
    layout0 = grad_sync_lib.bucket_layout(params_sds, gcfg0)
    total_bytes = sum(b["nbytes"] for b in layout0)
    knee = autotune.analytic_knee_bytes(strategy, x, y, hw)
    default_grid = autotune.candidate_bucket_bytes(knee, total_bytes)
    cand = sorted(set(candidates)) if candidates else default_grid

    rows = []
    for b in cand:
        gcfg = dataclasses.replace(gcfg0, bucket_bytes=b)
        layout = grad_sync_lib.bucket_layout(params_sds, gcfg)
        floor = max(16, min(1024, min(e["nbytes"] for e in layout)))
        m = collectives.bucketed_comm_cost_model(
            strategy, total_bytes, b, x, y, hw.link_bw, hw.latency_s,
            backward_seconds=hw.backward_seconds)
        row = {"bucket_bytes": b, "num_buckets": len(layout),
               "exposed_seconds": m["exposed_seconds"],
               "serial_seconds": m["serial_seconds"]}
        if len(layout) <= max_hlo_buckets:
            t0 = time.time()

            def sync_only(grads, _gcfg=gcfg):
                return sync_tree(grads, grid, _gcfg)

            smapped = compat.shard_map(
                sync_only, mesh=mesh, in_specs=P(), out_specs=P(),
                axis_names=frozenset(mesh.axis_names), check_vma=False)
            hlo = jax.jit(smapped).lower(params_sds).compile().as_text()
            audit = hlo_stats.bucket_audit(hlo, min_bytes=floor)
            row.update({
                "num_exchanges": audit["num_exchanges"],
                "audit_by_kind": audit["by_kind"],
                "audit_dropped": {k: audit["dropped"][k]
                                  for k in ("count", "bytes", "min_bytes")},
                "hlo_matches_schedule":
                    audit["num_exchanges"] == len(layout),
                "compile_s": round(time.time() - t0, 1),
            })
        else:
            row["hlo_skipped"] = (f"{len(layout)} buckets > "
                                  f"max_hlo_buckets={max_hlo_buckets}; "
                                  "cost-model row only")
        rows.append(row)
        print(f"[sweep] bucket_bytes={b:>12d}  buckets={len(layout):>5d}  "
              f"exposed={m['exposed_seconds'] * 1e6:9.1f}us  "
              f"hlo_exchanges={row.get('num_exchanges', '-')}")

    # the "auto" pick, evaluated over the union of the sweep's candidates
    # and the default grid -- same rule resolve_sync_config applies, so
    # the <=10%-of-best gate holds whenever the model is self-consistent
    union = sorted(set(cand) | set(default_grid))
    rec = autotune.recommend_bucket_bytes(strategy, x, y, hw,
                                          total_bytes=total_bytes,
                                          candidates=union, slack=slack)
    refined = autotune.refine_from_sweep(rows, strategy, x, y, hw,
                                         total_bytes=total_bytes,
                                         slack=slack)

    def exposed_at(b):
        return collectives.bucketed_comm_cost_model(
            strategy, total_bytes, b, x, y, hw.link_bw, hw.latency_s,
            backward_seconds=hw.backward_seconds)["exposed_seconds"]

    best_row = min(rows, key=lambda r: r["exposed_seconds"])
    checks = {
        "auto_within_10pct_of_sweep_best":
            rec["exposed_seconds"] <= 1.10 * best_row["exposed_seconds"],
        "auto_beats_fused":
            rec["exposed_seconds"] < exposed_at(0),
        "auto_beats_legacy_4mib":
            rec["exposed_seconds"]
            < exposed_at(autotune.LEGACY_DEFAULT_BUCKET_BYTES),
        "auto_within_sweep_bracket":
            autotune.pick_within_bracket(rec["bucket_bytes"],
                                         refined["bracket"]),
    }
    result = {
        "mode": "bucket_sweep", "arch": arch_id,
        "arch_variant": "smoke" if smoke_arch else "full",
        "mesh": mesh_name, "chips": int(mesh.devices.size),
        "strategy_requested": sync_strategy, "strategy": strategy,
        "resolve_events": resolve_events or None,
        "comm_dtype": str(jnp.dtype(comm_dtype)),
        "total_bytes": total_bytes,
        "hw": dataclasses.asdict(hw),
        "analytic_knee_bytes": knee,
        "rows": rows,
        "auto": {"bucket_bytes": rec["bucket_bytes"],
                 "num_buckets": rec["num_buckets"],
                 "exposed_seconds": rec["exposed_seconds"]},
        "refined": refined,
        "checks": checks,
    }
    if save:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"bucket_sweep__{arch_id}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
        print(f"[sweep] wrote {path}")
    failed = [k for k, ok in checks.items() if not ok]
    if failed:
        raise SystemExit(
            f"[sweep] FAILED gates: {failed}; auto pick "
            f"{rec['bucket_bytes']} (exposed "
            f"{rec['exposed_seconds'] * 1e6:.1f}us) vs sweep best "
            f"{best_row['bucket_bytes']} "
            f"({best_row['exposed_seconds'] * 1e6:.1f}us)")
    print(f"[sweep] OK: auto bucket_bytes={rec['bucket_bytes']} "
          f"({rec['num_buckets']} buckets, "
          f"exposed {rec['exposed_seconds'] * 1e6:.1f}us) within "
          f"bracket [{refined['bracket']['low']}, "
          f"{refined['bracket']['high']}] of sweep best "
          f"{refined['bracket']['best_bucket_bytes']}")
    return result


def chaos_train(fault_step: int, out_dir: str = "experiments/dryrun",
                max_steps: int = 8, metrics_out: str | None = None,
                trace_out: str | None = None) -> dict:
    """Elastic-recovery smoke: run a real (tiny) training loop on the
    8-device mesh, kill torus axis "dy" permanently at ``fault_step``, and
    require the run to finish every planned step via a mid-run
    torus2d->ring downgrade + checkpoint rollback (docs/robustness.md,
    "Elastic recovery"). Writes ``<out_dir>/chaos_train.json``; raises
    ``SystemExit`` if the run aborts or the recovery is not visible in the
    event stream -- the CI chaos-smoke job gates on exactly this.

    ``fault_step < 0`` runs the same loop **fault-free** (no FaultPlan)
    with inverted gates -- completion with zero recovery/downgrade events
    and a zero ``elastic/recoveries`` counter -- and writes
    ``train_smoke.json`` instead. ``metrics_out`` / ``trace_out`` route
    the run's telemetry (metrics JSONL, Chrome trace) to files
    (docs/observability.md); the recovery counters in the JSONL's summary
    row are what CI cross-checks against the event-stream gates.
    """
    import shutil
    import tempfile

    import numpy as np

    from repro.core.schedules import BatchSchedule, BatchStage
    from repro.core.batch_control import build_plan
    from repro.data.synthetic import SyntheticImageNet
    from repro.models import resnet
    from repro.obs import ObsConfig, Telemetry
    from repro.train.state import TrainState
    from repro.train.trainer import Trainer, TrainerConfig

    faulty = fault_step >= 0
    tag = "chaos-train" if faulty else "train-smoke"
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    cfg = resnet.ResNetConfig.tiny(num_classes=4)
    data = SyntheticImageNet(num_classes=4, image_size=32, noise=0.3)

    def loss_fn(params, batch, dp_axes):
        images, labels = batch
        logits = resnet.apply(params, images, cfg, dp_axes=dp_axes)
        return (losses.label_smoothing_xent(logits, labels, 0.1),
                jnp.zeros((), jnp.float32))

    plan = build_plan(BatchSchedule((BatchStage(0, 1.0, 2),)),
                      dataset_size=256, n_workers=8, max_steps=max_steps)
    obs_cfg = ObsConfig(metrics_path=metrics_out, trace_path=trace_out)
    tcfg = TrainerConfig(grad_sync=GradSyncConfig(strategy="torus2d"),
                         log_every=1, ckpt_every_steps=2, ckpt_keep_last=10,
                         retry_backoff_s=1e-4, obs=obs_cfg)
    fault_plan = (FaultPlan(axis_down_events=(("dy", fault_step),))
                  if faulty else None)
    ckpt_dir = tempfile.mkdtemp(prefix="chaos_train_ckpt_")
    completed, error = False, None
    state = TrainState.create(resnet.init(jax.random.key(0), cfg))
    # caller-owned telemetry: the registry snapshot must survive run() so
    # the result can record the recovery counters next to the event gates
    tel = Telemetry(obs_cfg, meta={
        "source": tag, "fault_step": fault_step, "planned_steps": max_steps})
    trainer = Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
                      cfg=tcfg, plan=plan,
                      data_fn=lambda i, gb: data.batch(i, gb),
                      checkpoint_dir=ckpt_dir, fault_plan=fault_plan,
                      telemetry=tel)
    t0 = time.time()
    try:
        state, history = trainer.run(state)
        completed = True
    except Exception as e:  # noqa: BLE001 -- the abort IS the test failure
        error = repr(e)
        history = []
        traceback.print_exc()
    finally:
        tel.close()   # summary row + Chrome trace, even on abort
        shutil.rmtree(ckpt_dir, ignore_errors=True)

    events = [h for h in history if h.get("kind") == "event"]
    downgrades = [e for e in events if e["event"] == "grad_sync_downgrade"]
    recoveries = [e for e in events if e["event"] == "elastic_recovery"]
    steps_done = int(state.step) if completed else 0
    losses_seen = [h["loss"] for h in history if "loss" in h]
    snap = tel.registry.snapshot()

    def counter_of(name):
        return int(snap.get(name, {}).get("value", 0))

    result = {
        "mode": "chaos_train" if faulty else "train_smoke",
        "mesh": "2x4", "chips": 8, "run_id": tel.run_id,
        "fault": ({"axis": "dy", "down_from_step": fault_step}
                  if faulty else None),
        "planned_steps": max_steps, "steps": steps_done,
        "completed": completed, "error": error,
        "wall_s": round(time.time() - t0, 1),
        "loss_finite": bool(np.all(np.isfinite(losses_seen))),
        "metrics_out": metrics_out, "trace_out": trace_out,
        "recovery_counters": {
            "elastic/recoveries": counter_of("elastic/recoveries"),
            "elastic/permanent_failures":
                counter_of("elastic/permanent_failures"),
            "events/elastic_recovery": counter_of("events/elastic_recovery"),
        },
        "events": events,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(
        out_dir, "chaos_train.json" if faulty else "train_smoke.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    print(f"[{tag}] wrote {path}")

    problems = []
    if not completed:
        problems.append(f"run aborted: {error}")
    elif steps_done != max_steps:
        problems.append(f"finished {steps_done}/{max_steps} steps")
    if not result["loss_finite"]:
        problems.append("non-finite loss in history")
    if faulty:
        if not any(d.get("context") == "elastic" for d in downgrades):
            problems.append("no mid-run grad_sync_downgrade event")
        if not recoveries:
            problems.append("no elastic_recovery event")
        if counter_of("elastic/recoveries") < 1:
            problems.append("elastic/recoveries counter is zero")
    else:
        if downgrades or recoveries:
            problems.append(
                f"fault-free run saw {len(downgrades)} downgrade / "
                f"{len(recoveries)} recovery events")
        if counter_of("elastic/recoveries") != 0:
            problems.append("fault-free run has nonzero elastic/recoveries")
    if problems:
        raise SystemExit(f"[{tag}] FAILED: " + "; ".join(problems))
    if faulty:
        print(f"[{tag}] OK: axis dy died at step {fault_step}, run "
              f"finished {steps_done}/{max_steps} steps "
              f"(downgrade {downgrades[0]['from']}->{downgrades[0]['to']}, "
              f"rollback to step {recoveries[0]['step']})")
    else:
        print(f"[{tag}] OK: fault-free run finished "
              f"{steps_done}/{max_steps} steps, zero recovery events")
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--sync", default="torus2d",
                    choices=["psum", "ring", "hierarchical", "torus2d"])
    ap.add_argument("--bucket-bytes", type=_bucket_bytes_arg, default=0,
                    help="gradient-sync bucket size target; 0 = single fused "
                         "buffer; 'auto' = autotuned at resolve time "
                         "(see docs/gradient_sync.md)")
    ap.add_argument("--sweep-bucket-bytes", action="store_true",
                    help="bucket-size sweep: compile the sync-only program "
                         "per candidate bucket_bytes at production scale, "
                         "audit the HLO, gate the autotuner's pick against "
                         "the measured optimum bracket, and save "
                         "bucket_sweep__<arch>__<mesh>.json")
    ap.add_argument("--smoke-arch", action="store_true",
                    help="--sweep-bucket-bytes: use the arch's smoke "
                         "variant (CI-sized; full archs near the knee need "
                         "thousands of buckets, which the sweep skips "
                         "compiling)")
    ap.add_argument("--inject-faults", action="store_true",
                    help="mark the leading DP torus axis down "
                         "(testing/chaos.FaultPlan): the grad-sync strategy "
                         "must degrade along the fallback chain instead of "
                         "aborting; events land in the JSON "
                         "(docs/robustness.md)")
    ap.add_argument("--chaos-train", action="store_true",
                    help="elastic-recovery smoke: run a real tiny training "
                         "loop (8-device mesh), kill a torus axis "
                         "permanently mid-run, and require completion via "
                         "mid-run downgrade + checkpoint rollback")
    ap.add_argument("--fault-step", type=int, default=3,
                    help="step at which --chaos-train kills the axis; "
                         "negative runs the same loop fault-free "
                         "(train_smoke.json, inverted gates)")
    ap.add_argument("--metrics-out", default=None,
                    help="--chaos-train: write the run's metrics/event "
                         "JSONL here (docs/observability.md)")
    ap.add_argument("--trace-out", default=None,
                    help="--chaos-train: write a Chrome trace_event JSON "
                         "of the run's host spans here")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    if args.chaos_train:
        chaos_train(args.fault_step, args.out,
                    metrics_out=args.metrics_out, trace_out=args.trace_out)
        return

    if args.sweep_bucket_bytes:
        if not args.arch:
            raise SystemExit("--sweep-bucket-bytes needs --arch")
        sweep_bucket_bytes(args.arch, multi_pod=args.multi_pod,
                           sync_strategy=args.sync, out_dir=args.out,
                           smoke_arch=args.smoke_arch)
        return

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    archs = registry.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = []
    for mp in meshes:
        for arch_id in archs:
            for shape_name in shapes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch_id}__{shape_name}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[SKIP] {arch_id} {shape_name} {mesh_name}")
                    continue
                if (SHAPES[shape_name].step == "train"
                        and arch_id not in FSDP_ARCHS
                        and not compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES):
                    print(f"[SKIP] {arch_id} {shape_name} {mesh_name}: "
                          "partial-manual shard_map train step needs "
                          "jax >= 0.5 on this jaxlib")
                    continue
                try:
                    fault_plan = None
                    if args.inject_faults:
                        # down the leading DP axis: the slow inter-pod axis
                        # on the 2-pod mesh, the whole data ring otherwise
                        mesh_dp = ("pod", "data") if mp else ("data",)
                        fault_plan = FaultPlan(down_axes=(mesh_dp[0],))
                    run_one(arch_id, shape_name, mp, args.sync, args.out,
                            bucket_bytes=args.bucket_bytes,
                            fault_plan=fault_plan)
                except Exception as e:  # noqa: BLE001
                    failures.append((arch_id, shape_name, mp, repr(e)))
                    print(f"[FAIL] {arch_id} {shape_name} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run failures: {failures}")
    print("ALL DRY-RUNS PASSED")


if __name__ == "__main__":
    main()
