"""Perf hillclimbing driver (§Perf): re-lower a (arch x shape) under a
named variant, extract roofline terms, and log hypothesis -> result.

    PYTHONPATH=src python -m repro.launch.perf --exp <name>
    PYTHONPATH=src python -m repro.launch.perf --list

Each experiment is a function returning a list of variant records; results
append to experiments/perf/<exp>.json. Variants re-use the dry-run builders
so numbers are directly comparable with the §Roofline baselines.
"""

import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES
from repro import compat
from repro.launch import hlo_stats
from repro.launch.mesh import (cache_pspecs, dp_axes_of, make_factorized_mesh,
                               make_production_mesh, param_pspecs,
                               with_shardings)
from repro.models import transformer as T

PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9


def _measure(fn, args, *, step: str, label: str, n_blocks_pair=None) -> dict:
    """Compile and extract roofline terms. If ``n_blocks_pair`` is given as
    ((fn1, args1), (fn2, args2), n_blocks), scan-extrapolate the costs."""
    t0 = time.time()
    compiled = fn.lower(*args).compile()
    dt = time.time() - t0
    ca = compat.cost_analysis(compiled)
    coll = hlo_stats.collective_stats(compiled.as_text())
    flops = ca.get("flops", 0.0)
    bytes_acc = ca.get("bytes accessed", 0.0)
    coll_total = coll["total_wire_bytes"]
    f32 = coll["wire_by_dtype"].get("f32", 0)
    if n_blocks_pair is not None:
        (f1, a1), (f2, a2), nb = n_blocks_pair
        e1 = _extract_cost(f1, a1)
        e2 = _extract_cost(f2, a2)
        flops = e1["flops"] + (e2["flops"] - e1["flops"]) * (nb - 1)
        bytes_acc = (e1["bytes"] + (e2["bytes"] - e1["bytes"]) * (nb - 1))
        coll_total = (e1["coll"] + (e2["coll"] - e1["coll"]) * (nb - 1))
        f32 = e1["f32"] + (e2["f32"] - e1["f32"]) * (nb - 1)
    if step == "train":
        coll_total -= f32 / 2        # bf16-exchange correction (see dryrun)
    mem = compiled.memory_analysis()
    rec = {
        "label": label,
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll_total / ICI_BW,
        "coll_bytes": coll_total,
        "coll_counts": {k: coll[k]["count"] for k in
                        ("all-reduce", "all-gather", "reduce-scatter",
                         "all-to-all", "collective-permute")},
        "temp_gib": (getattr(mem, "temp_size_in_bytes", 0) or 0) / 2**30,
        "compile_s": round(dt, 1),
    }
    rec["dominant"] = max(("compute_s", "memory_s", "collective_s"),
                          key=lambda k: rec[k])
    return rec


def _extract_cost(fn, args):
    c = fn.lower(*args).compile()
    ca = compat.cost_analysis(c)
    coll = hlo_stats.collective_stats(c.as_text())
    return {"flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
            "coll": coll["total_wire_bytes"],
            "f32": coll["wire_by_dtype"].get("f32", 0)}


def _cost_cfg(cfg, k, seq_len):
    return dataclasses.replace(
        cfg, n_layers=cfg.n_prefix + k * len(cfg.pattern), scan_blocks=False,
        q_chunk_unroll=True, ssm_unroll=True)


def measure_train(arch_id, shape_name, mesh, sync, label, fuse=None,
                  extrapolate=True):
    from repro.launch import dryrun as D
    shape = SHAPES[shape_name]
    cfg = D.arch_for(arch_id, shape)
    fn, args = D.build_train(arch_id, cfg, shape, mesh, sync, fuse=fuse)
    pair = None
    if extrapolate:
        pair = tuple(
            D.build_train(arch_id, _cost_cfg(cfg, k, shape.seq_len), shape,
                          mesh, sync, fuse=fuse) for k in (1, 2)) + (cfg.n_blocks,)
    return _measure(fn, args, step="train", label=label, n_blocks_pair=pair)


def measure_decode(arch_id, shape_name, mesh, label, cfg_patch=None,
                   cache_override=None, extrapolate=True):
    from repro.launch import dryrun as D
    shape = SHAPES[shape_name]
    cfg = D.arch_for(arch_id, shape)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)

    def build(c):
        return D.build_decode(arch_id, c, shape, mesh)

    fn, args = build(cfg)
    if cache_override is not None:
        args = (args[0], args[1], cache_override(cfg, args[2]), args[3])
    pair = None
    if extrapolate:
        pair = tuple(build(_cost_cfg(cfg, k, shape.seq_len))
                     for k in (1, 2)) + (cfg.n_blocks,)
    return _measure(fn, args, step="decode", label=label, n_blocks_pair=pair)


def save(exp_name: str, records: list):
    os.makedirs("experiments/perf", exist_ok=True)
    path = f"experiments/perf/{exp_name}.json"
    with open(path, "w") as f:
        json.dump(records, f, indent=1)
    for r in records:
        print(f"{exp_name:28s} {r['label']:42s} "
              f"cmp {r['compute_s']:.2e} mem {r['memory_s']:.2e} "
              f"coll {r['collective_s']:.2e} dom={r['dominant']}")
    return path


# ---------------------------------------------------------------------------
# Experiments
# ---------------------------------------------------------------------------

def exp_sync_strategies():
    """Paper Table-2 analogue at HLO level: gradient-sync strategy sweep on
    gemma-7b train_4k, single-pod (1D data ring) and multi-pod (2D torus)."""
    out = []
    for mp in (False, True):
        mesh = make_production_mesh(multi_pod=mp)
        mname = "2pod" if mp else "1pod"
        for sync in ("psum", "ring", "hierarchical", "torus2d"):
            out.append(measure_train("gemma-7b", "train_4k", mesh, sync,
                                     f"{mname}/{sync}"))
    return out


def exp_factorized_torus():
    """Beyond-production-mesh: factorize the single pod's data axis into a
    4x4 torus (paper Table 4 style) so the 2D decomposition exists INSIDE
    one pod; compare vs the flat 16-ring."""
    out = []
    flat = make_production_mesh()
    out.append(measure_train("gemma-7b", "train_4k", flat, "torus2d",
                             "flat data=16 (1D ring)"))
    fact = make_factorized_mesh(data_y=4, data_x=4, model=16)
    out.append(measure_train("gemma-7b", "train_4k", fact, "torus2d",
                             "factorized 4x4 torus"))
    out.append(measure_train("gemma-7b", "train_4k", fact, "hierarchical",
                             "factorized 4x4 hierarchical"))
    out.append(measure_train("gemma-7b", "train_4k", fact, "ring",
                             "factorized flat ring (control)"))
    return out


def exp_kimi_decode():
    """kimi-k2 decode_32k: collective-bound MoE decode. Variants attack the
    dispatch/combine traffic."""
    mesh = make_production_mesh()
    out = [measure_decode("kimi-k2-1t-a32b", "decode_32k", mesh, "baseline")]
    # capacity factor 1.0 (fewer padded slots to move)
    out.append(measure_decode("kimi-k2-1t-a32b", "decode_32k", mesh,
                              "capacity 1.0",
                              cfg_patch={"moe_capacity_factor": 1.0}))
    return out


def measure_decode_2dtp(arch_id, shape_name, mesh, label):
    """Decode variant: weights 2D-sharded over (data x model) with the token
    batch REPLICATED over data -- turns the per-token FSDP weight all-gather
    into cheap activation psums (weight-stationary serving). The KV cache
    stays batch-sharded over data (it must -- ~2 TB at 405B/32k/128)."""
    from repro.launch import dryrun as D
    shape = SHAPES[shape_name]
    cfg = D.arch_for(arch_id, shape)

    def build(c):
        dp = dp_axes_of(mesh)
        params_sds = jax.eval_shape(lambda: T.init(jax.random.key(0), c))
        params_sds = with_shardings(
            params_sds, mesh, param_pspecs(params_sds, fsdp=True, mesh=mesh))
        B = shape.global_batch
        cache_sds = jax.eval_shape(lambda: T.init_cache(c, B, shape.seq_len))
        cache_sds = with_shardings(cache_sds, mesh,
                                   cache_pspecs(cache_sds, dp, mesh))
        token = jax.ShapeDtypeStruct((B, 1), jnp.int32,
                                     sharding=NamedSharding(mesh, P()))
        index = jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=NamedSharding(mesh, P()))

        def fn(params, token, cache, index):
            return T.decode_step(params, token, cache, index, c)

        return jax.jit(fn), (params_sds, token, cache_sds, index)

    fn, args = build(cfg)
    pair = tuple(build(_cost_cfg(cfg, k, shape.seq_len))
                 for k in (1, 2)) + (cfg.n_blocks,)
    return _measure(fn, args, step="decode", label=label, n_blocks_pair=pair)


def exp_llama_decode():
    """llama3-405b decode_32k: collective-bound (per-token FSDP weight
    all-gathers). Variant: 2D-TP weight-stationary serving."""
    mesh = make_production_mesh()
    out = [measure_decode("llama3-405b", "decode_32k", mesh,
                          "baseline fsdp+batch-sharded")]
    out.append(measure_decode_2dtp("llama3-405b", "decode_32k", mesh,
                                   "2D-TP weight-stationary"))
    return out


EXPERIMENTS = {
    "sync_strategies": exp_sync_strategies,
    "factorized_torus": exp_factorized_torus,
    "kimi_decode": exp_kimi_decode,
    "llama_decode": exp_llama_decode,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default=None)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list or not args.exp:
        print("\n".join(EXPERIMENTS))
        return
    save(args.exp, EXPERIMENTS[args.exp]())


if __name__ == "__main__":
    main()
