"""Production meshes and parameter-sharding rules.

``make_production_mesh``: the fixed target -- 16x16 = 256 chips per pod
(``data`` x ``model``), 2 pods = 512 chips multi-pod (``pod`` axis leading).

``make_factorized_mesh``: the framework's native expression of the paper's
2D-Torus *within* a pod -- the data axis split into (data_y, data_x) rings
so the torus phases map onto two physical ICI dimensions (paper Table 4
grids). Used by the perf experiments; the dry-run keeps the contract mesh.

``param_pspecs``: path-based sharding rules (megatron-style TP over
``model`` + optional fsdp over ``data``). Scanned-block leaves carry a
leading (n_blocks,) dim -> specs are right-aligned against leaf rank.
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_factorized_mesh(*, data_y: int = 4, data_x: int = 4,
                         model: int = 16):
    """Single-pod mesh with the data axis factorized into the 2D torus."""
    return jax.make_mesh((data_y, data_x, model), ("data_y", "data_x", "model"))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


# ---------------------------------------------------------------------------
# Parameter sharding rules
# ---------------------------------------------------------------------------

# (regex on the parameter path) -> spec for the *trailing* dims of the leaf.
# "F" is replaced by the fsdp axis ("data") when fsdp is on, else None.
_RULES: tuple[tuple[str, tuple], ...] = (
    (r"embedding$", ("model", "F")),              # (V, d) vocab-sharded
    (r"unembed/kernel$", ("F", "model")),         # (d, V)
    (r"(q|k|v|up|gate|in_x|in_gate)/kernel$", ("F", "model")),
    (r"(o|down|out|out_proj)/kernel$", ("model", "F")),
    (r"experts/(up|gate)$", ("model", "F", None)),  # (E, d, f) expert-parallel
    (r"experts/down$", ("model", None, "F")),       # (E, f, d)
    (r"router/kernel$", (None, None)),
    (r"in_proj/kernel$", ("model", None)),        # ssd packed proj: row-parallel
    (r"conv/kernel$", (None, None)),
    (r"(rg|ig)_kernel$", (None, "model")),
    (r"(rg|ig)_bias$", ("model",)),
    (r"lambda_param$", ("model",)),
    (r"(A_log|D|dt_bias)$", (None,)),
    (r"(norm_scale|norm_bias|bn_scale|bn_bias)$", (None,)),
    (r".*", None),                                # default: replicated
)


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def param_pspecs(params, *, fsdp: bool = False, mesh=None):
    """PartitionSpec pytree for a parameter tree (works on SDS trees too).

    Divisibility-aware: when a rule assigns a mesh axis to a dim it does not
    evenly divide (granite's 40 experts vs model=16; mamba's 50280 vocab),
    the axis is moved to the next trailing dim it divides, else dropped.
    """
    f = "data" if fsdp else None
    sizes = ({a: int(s) for a, s in mesh.shape.items()} if mesh is not None
             else {})

    def fixup(tr: tuple, shape: tuple) -> tuple:
        tr = list(tr)
        for i, ax in enumerate(tr):
            if ax is None or not sizes:
                continue
            if shape[i] % sizes.get(ax, 1) == 0:
                continue
            tr[i] = None
            for j in range(len(tr)):          # move to a dim it divides
                if tr[j] is None and shape[j] % sizes.get(ax, 1) == 0:
                    tr[j] = ax
                    break
        return tuple(tr)

    def spec_for(path, leaf):
        ps = _path_str(path)
        for pat, trailing in _RULES:
            if re.search(pat, ps):
                if trailing is None:
                    return P()
                tr = tuple(f if t == "F" else t for t in trailing)
                # right-align: scanned blocks have a leading (n_blocks,) dim
                lead = leaf.ndim - len(tr)
                if lead < 0:
                    return P()
                tr = fixup(tr, leaf.shape[lead:])
                if all(t is None for t in tr):
                    return P()
                return P(*((None,) * lead + tr))
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)


def with_shardings(tree, mesh, specs):
    """Attach NamedShardings to a ShapeDtypeStruct tree (for .lower())."""
    return jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
        tree, specs)


def cache_pspecs(cache, dp_axes: tuple[str, ...], mesh):
    """KV/recurrent-state sharding, divisibility-aware.

    kv cache (B, L, Hkv, D): batch over DP axes (when divisible), model on
    Hkv if divisible else on D (qwen/llama kv=8 < model=16 -> shard the
    head_dim instead). Recurrent/conv states: batch over DP, model on the
    first trailing dim it divides. Scanned leaves ('blocks/...') carry a
    leading (n_blocks,) dim that stays unsharded.
    """
    dp_size = int(np.prod([mesh.shape[a] for a in dp_axes]))
    model_size = int(mesh.shape.get("model", 1))

    def spec_with_scan(path, leaf):
        ps = _path_str(path)
        scanned = ps.startswith("blocks")
        nd = leaf.ndim - (1 if scanned else 0)
        dims = leaf.shape[1:] if scanned else leaf.shape
        if nd == 0:
            return P()
        batch_ax = dp_axes if dims[0] % max(dp_size, 1) == 0 else None
        rest = [None] * (nd - 1)
        if nd == 4:                       # (B, L, Hkv, D) kv cache
            if dims[2] % model_size == 0:
                rest[1] = "model"
            elif dims[3] % model_size == 0:
                rest[2] = "model"
        else:                             # recurrent / conv state
            for i, d in enumerate(dims[1:]):
                if d % model_size == 0:
                    rest[i] = "model"
                    break
        inner = (batch_ax, *rest)
        if scanned:
            inner = (None,) + inner
        return P(*inner)

    return jax.tree_util.tree_map_with_path(spec_with_scan, cache)
