"""Crash-consistent checkpointing: atomic npz + CRC manifest sidecar.

Format (docs/robustness.md):

* ``<name>.npz``           -- flat path-keyed leaves (params, LARS momentum,
  step, guard state). Works for any pytree of arrays; leaves are gathered
  to host (fine at this container's scale; on a real pod each host writes
  its own shard -- the path-keyed format is already per-leaf).
* ``<name>.manifest.json`` -- sidecar carrying format version, step,
  optional trainer metadata (stage info), and per-leaf CRC32/shape/dtype.

Commit protocol: payload is written to a tmp file, fsync'd, and
``os.replace``'d into place; the manifest follows the same tmp+fsync+rename
dance *after* the payload rename. The manifest is therefore the commit
record -- an npz without a manifest is an uncommitted torso (a crash
between the two renames) and is ignored by ``latest``/``latest_valid``.
A crash at any point leaves either the previous complete checkpoint or a
new complete one, never a half-written file under a committed name.

``save`` retries transient IO errors with jittered exponential backoff
(``repro.utils.retry``) and prunes to ``keep_last`` checkpoints
(step-ordered). ``latest`` orders by *step* parsed from the manifest
(filename fallback) -- never by mtime, which lies for copied/restored
files. ``restore`` verifies CRCs and shapes and raises
:class:`CheckpointCorruptError` with the offending leaf; ``latest_valid``
walks candidates newest-first and returns the first that passes
validation, so a corrupt newest checkpoint falls back to the previous
valid one instead of killing the job.

:class:`AsyncCheckpointWriter` moves the commit off the training thread:
``save`` snapshots the state to host numpy buffers (the only part the
caller pays for) and enqueues the write; a single worker thread runs the
identical tmp+fsync+rename protocol, so everything above --
``latest`` / ``latest_valid`` / ``restore`` / crash consistency -- holds
unchanged for async checkpoints. The queue is bounded (backpressure, not
unbounded host memory), commits land in enqueue order, failures surface as
drained events plus ``errors``, and ``flush``/``close`` give the trainer a
durability barrier (it flushes before any restore decision).
"""

from __future__ import annotations

import collections
import json
import os
import queue as queue_lib
import re
import threading
import time
import zlib
from typing import Callable

import jax
import numpy as np

from repro.obs.metrics import NULL_REGISTRY
from repro.train.state import TrainState
from repro.utils.retry import retry_call

_SEP = "::"
MANIFEST_SUFFIX = ".manifest.json"
FORMAT_VERSION = 1
_STEP_RE = re.compile(r"step_(\d+)")

#: Guard-state scalars added after the first checkpoint format; restored
#: with these defaults when absent so old checkpoints keep loading.
_OPTIONAL_SCALARS = {"loss_scale": (1.0, np.float32),
                     "good_steps": (0, np.int32)}


class CheckpointError(RuntimeError):
    """Checkpoint IO failed (after retries)."""


class CheckpointCorruptError(CheckpointError):
    """The checkpoint on disk is truncated, tampered, or incomplete."""


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


def _payload_of(state: TrainState) -> dict[str, np.ndarray]:
    payload = {}
    for prefix, tree in (("params", state.params),
                         ("opt", state.opt_state)):
        for k, v in _flatten(tree).items():
            payload[f"{prefix}{_SEP}{k}"] = v
    payload["step"] = np.asarray(state.step)
    payload["loss_scale"] = np.asarray(state.loss_scale)
    payload["good_steps"] = np.asarray(state.good_steps)
    return payload


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def manifest_path(path: str) -> str:
    return path[: -len(".npz")] + MANIFEST_SUFFIX if path.endswith(".npz") \
        else path + MANIFEST_SUFFIX


def _atomic_write(path: str, write_fn: Callable, io_hook=None,
                  hook_phase: str = "", attempt: int = 0) -> None:
    """tmp + (hook) + fsync + rename. The hook fires after the bytes are
    written but before they are durable -- the crash window fault injection
    targets (testing/chaos.py)."""
    tmp = path + ".tmp"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            if io_hook is not None:
                io_hook(hook_phase, attempt)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            try:
                os.unlink(tmp)
            except OSError:
                pass


def _manifest_of(payload: dict[str, np.ndarray], step: int, name: str,
                 meta: dict | None) -> dict:
    return {
        "format_version": FORMAT_VERSION,
        "step": step,
        "name": name,
        "meta": meta or {},
        "leaves": {k: {"crc32": _crc(v), "shape": list(v.shape),
                       "dtype": str(v.dtype), "nbytes": int(v.nbytes)}
                   for k, v in payload.items()},
    }


def _commit(directory: str, path: str, payload: dict[str, np.ndarray],
            manifest: dict, *, retries: int, backoff_s: float,
            keep_last: int, io_hook, on_retry,
            metrics=NULL_REGISTRY) -> str:
    """The durable half of a save: atomic payload + manifest writes under
    the shared retry helper, then retention pruning. Runs on the caller
    thread for :func:`save`, on the worker thread for
    :class:`AsyncCheckpointWriter`. ``metrics`` (repro.obs.metrics)
    receives the commit-latency histogram and commit/failure counters."""
    os.makedirs(directory, exist_ok=True)
    attempt_box = [0]

    def once():
        a = attempt_box[0]
        attempt_box[0] += 1
        if io_hook is not None:
            io_hook("begin", a)
        _atomic_write(path, lambda f: np.savez(f, **payload),
                      io_hook, "payload", a)
        _atomic_write(manifest_path(path),
                      lambda f: f.write(json.dumps(manifest).encode()),
                      io_hook, "manifest", a)

    t0 = time.monotonic()
    try:
        retry_call(once, retries=retries, backoff_s=backoff_s,
                   retry_on=(OSError,), on_retry=on_retry,
                   seed=manifest["step"])
    except OSError as e:
        metrics.counter("checkpoint/failures").inc()
        raise CheckpointError(
            f"checkpoint write failed after {retries + 1} attempts: "
            f"{e}") from e
    metrics.histogram("checkpoint/commit_s").observe(time.monotonic() - t0)
    metrics.counter("checkpoint/commits").inc()
    if keep_last > 0:
        _prune(directory, keep_last)
    return path


def _prepare(directory: str, state: TrainState, name: str | None,
             meta: dict | None):
    """Host snapshot + manifest: the synchronous part of every save."""
    step = int(state.step)
    name = name or f"step_{step:08d}"
    path = os.path.join(directory, f"{name}.npz")
    payload = _payload_of(state)
    return path, payload, _manifest_of(payload, step, name, meta)


def save(directory: str, state: TrainState, name: str | None = None, *,
         retries: int = 3, backoff_s: float = 0.05, keep_last: int = 0,
         meta: dict | None = None, io_hook=None, on_retry=None,
         metrics=NULL_REGISTRY) -> str:
    """Atomically write ``state`` and its manifest; returns the npz path.

    ``io_hook(phase, attempt)`` (phases ``begin``/``payload``/``manifest``)
    may raise to simulate a crash; OSErrors are retried ``retries`` times
    with jittered exponential backoff starting at ``backoff_s``, reporting
    each retried attempt to ``on_retry(attempt, exc)``. ``keep_last > 0``
    prunes to the newest K checkpoints by step after a successful write.
    ``metrics`` records commit latency/outcome (repro.obs.metrics).
    """
    path, payload, manifest = _prepare(directory, state, name, meta)
    return _commit(directory, path, payload, manifest, retries=retries,
                   backoff_s=backoff_s, keep_last=keep_last,
                   io_hook=io_hook, on_retry=on_retry, metrics=metrics)


class AsyncCheckpointWriter:
    """Commit checkpoints off the training thread.

    ``save`` costs the caller exactly one host snapshot (``np.asarray`` of
    every leaf -- device->host copies, so later donation of the device
    buffers is safe) and one bounded-queue put; the tmp+fsync+rename commit
    protocol, retries, and retention pruning run on a single daemon worker
    thread, in enqueue order. At most ``max_pending`` saves wait in the
    queue (plus one in flight); a full queue blocks ``save`` -- bounded
    host memory, never a dropped checkpoint.

    Outcomes surface two ways: as history-event dicts via
    :meth:`drain_events` (``checkpoint`` / ``checkpoint_retry`` /
    ``checkpoint_failed``, same schema the synchronous trainer path emits)
    and as :class:`CheckpointError` instances in :attr:`errors`. A commit
    failure never kills the worker -- the run continues on the previous
    checkpoint, exactly like the synchronous path.

    ``flush`` blocks until every enqueued save is durable (the trainer's
    barrier before restore decisions and at run end); ``close`` flushes,
    stops the worker, and leaves the instance unusable.

    ``metrics`` (repro.obs.metrics registry, shared with the trainer)
    observes the writer from both threads: a ``checkpoint/queue_depth``
    gauge tracks saves enqueued or in flight, and every commit lands in
    the ``checkpoint/commit_s`` latency histogram plus commit/failure
    counters -- the registry is lock-protected, so cross-thread recording
    is safe.
    """

    def __init__(self, *, max_pending: int = 2, retries: int = 3,
                 backoff_s: float = 0.05, metrics=NULL_REGISTRY):
        self._retries = retries
        self._backoff_s = backoff_s
        self._metrics = metrics
        self._queue: queue_lib.Queue = queue_lib.Queue(max(1, max_pending))
        self._events: collections.deque = collections.deque()
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._pending = 0
        self._closed = False
        self.errors: list[CheckpointError] = []
        self._worker = threading.Thread(
            target=self._loop, name="ckpt-writer", daemon=True)
        self._worker.start()

    def save(self, directory: str, state: TrainState,
             name: str | None = None, *, keep_last: int = 0,
             meta: dict | None = None, io_hook=None) -> str:
        """Snapshot ``state`` to host and enqueue the commit; returns the
        npz path the worker will write. Blocks only on the snapshot and on
        queue backpressure, never on payload IO."""
        if self._closed:
            raise CheckpointError("writer is closed")
        path, payload, manifest = _prepare(directory, state, name, meta)
        with self._lock:
            self._pending += 1
            self._metrics.gauge("checkpoint/queue_depth").set(self._pending)
        self._queue.put((directory, path, payload, manifest, keep_last,
                         io_hook))
        return path

    def pending(self) -> int:
        """Saves enqueued or in flight (0 == everything durable)."""
        with self._lock:
            return self._pending

    def drain_events(self, sink: Callable[[dict], None] | None = None
                     ) -> list[dict]:
        """Pop all completed-save events (oldest first); optionally feed
        each to ``sink``. Called from the training thread, so history stays
        single-writer."""
        out = []
        while True:
            try:
                ev = self._events.popleft()
            except IndexError:
                break
            if sink is not None:
                sink(ev)
            out.append(ev)
        return out

    def flush(self, timeout: float | None = None) -> bool:
        """Block until all enqueued saves are committed (or failed).
        Returns False on timeout."""
        with self._idle:
            return self._idle.wait_for(lambda: self._pending == 0, timeout)

    def close(self, timeout: float | None = None) -> None:
        """Flush, stop the worker, release the thread. Idempotent."""
        if self._closed:
            return
        self.flush(timeout)
        self._closed = True
        self._queue.put(None)
        self._worker.join(timeout)

    def _loop(self) -> None:
        while True:
            job = self._queue.get()
            if job is None:
                return
            directory, path, payload, manifest, keep_last, io_hook = job
            step = manifest["step"]
            try:
                _commit(directory, path, payload, manifest,
                        retries=self._retries, backoff_s=self._backoff_s,
                        keep_last=keep_last, io_hook=io_hook,
                        on_retry=lambda a, e: self._events.append(
                            {"event": "checkpoint_retry", "step": step,
                             "attempt": a, "error": str(e)}),
                        metrics=self._metrics)
                self._events.append({"event": "checkpoint", "step": step,
                                     "path": os.path.basename(path)})
            except CheckpointError as e:
                self.errors.append(e)
                self._events.append({"event": "checkpoint_failed",
                                     "step": step, "error": str(e)})
            except Exception as e:  # noqa: BLE001 -- worker must survive
                err = CheckpointError(f"async save of step {step} failed: "
                                      f"{type(e).__name__}: {e}")
                self.errors.append(err)
                self._events.append({"event": "checkpoint_failed",
                                     "step": step, "error": str(err)})
            finally:
                with self._idle:
                    self._pending -= 1
                    self._metrics.gauge("checkpoint/queue_depth").set(
                        self._pending)
                    self._idle.notify_all()


def load_manifest(path: str) -> dict | None:
    mp = manifest_path(path)
    if not os.path.exists(mp):
        return None
    try:
        with open(mp) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def validate(path: str, like: TrainState | None = None) -> dict:
    """Full integrity check; returns the manifest or raises
    :class:`CheckpointCorruptError` naming what is wrong."""
    if not os.path.exists(path):
        raise CheckpointCorruptError(f"{path}: missing")
    manifest = load_manifest(path)
    if manifest is None:
        raise CheckpointCorruptError(
            f"{path}: missing/unreadable manifest sidecar "
            f"({manifest_path(path)}) -- uncommitted or pre-manifest write")
    try:
        with np.load(path) as data:
            for key, info in manifest["leaves"].items():
                if key not in data:
                    raise CheckpointCorruptError(
                        f"{path}: leaf {key!r} listed in manifest but "
                        "missing from payload")
                arr = data[key]
                if list(arr.shape) != info["shape"]:
                    raise CheckpointCorruptError(
                        f"{path}: leaf {key!r} shape {list(arr.shape)} != "
                        f"manifest {info['shape']}")
                if _crc(arr) != info["crc32"]:
                    raise CheckpointCorruptError(
                        f"{path}: leaf {key!r} CRC mismatch (bit rot or "
                        "torn write)")
    except CheckpointCorruptError:
        raise
    except Exception as e:  # zipfile/np errors on truncated archives
        raise CheckpointCorruptError(
            f"{path}: unreadable payload ({type(e).__name__}: {e})") from e
    if like is not None:
        _check_structure(path, manifest, like)
    return manifest


def _check_structure(path: str, manifest: dict, like: TrainState) -> None:
    expected: dict[str, tuple[int, ...]] = {}
    for prefix, tree in (("params", like.params), ("opt", like.opt_state)):
        for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            key = f"{prefix}{_SEP}" + _SEP.join(
                str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
            expected[key] = tuple(leaf.shape)
    for key, shape in expected.items():
        info = manifest["leaves"].get(key)
        if info is None:
            raise CheckpointCorruptError(
                f"{path}: leaf {key!r} required by the target state is "
                "absent")
        if tuple(info["shape"]) != shape:
            raise CheckpointCorruptError(
                f"{path}: leaf {key!r} shape {tuple(info['shape'])} != "
                f"target {shape}")


def restore(path: str, like: TrainState, check: bool = True) -> TrainState:
    """Restore into the structure of ``like`` (shapes/dtypes validated).

    ``check=True`` (default) verifies the manifest + CRC32 of every leaf
    first and raises :class:`CheckpointCorruptError` on any mismatch.
    """
    if check:
        validate(path, like)
    try:
        npz = np.load(path)
    except Exception as e:
        raise CheckpointCorruptError(
            f"{path}: unreadable payload ({type(e).__name__}: {e})") from e
    with npz as data:
        def fill(prefix, tree):
            flat, _ = jax.tree_util.tree_flatten_with_path(tree)
            leaves = []
            for p, leaf in flat:
                key = prefix + _SEP + _SEP.join(
                    str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
                if key not in data:
                    raise CheckpointCorruptError(
                        f"{path}: missing leaf {key!r}")
                arr = data[key]
                if arr.shape != leaf.shape:
                    raise CheckpointCorruptError(
                        f"{path}: {key}: shape {arr.shape} != {leaf.shape}")
                leaves.append(arr.astype(leaf.dtype))
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tree), leaves)

        def scalar(key):
            if key in data:
                return jax.numpy.asarray(data[key])
            default, dtype = _OPTIONAL_SCALARS[key]
            return jax.numpy.asarray(default, dtype)

        return TrainState(params=fill("params", like.params),
                          opt_state=fill("opt", like.opt_state),
                          step=jax.numpy.asarray(data["step"]),
                          loss_scale=scalar("loss_scale"),
                          good_steps=scalar("good_steps"))


def _candidates(directory: str) -> list[tuple[int, str]]:
    """(step, path) for every committed-looking npz, step-ordered ascending.

    Step comes from the manifest; for manifest-less files (legacy format)
    fall back to the ``step_NNN`` filename convention, then to mtime order
    as a last resort (legacy behavior, kept so old dirs still resolve).
    """
    if not os.path.isdir(directory):
        return []
    out = []
    for f in sorted(os.listdir(directory)):
        if not f.endswith(".npz"):
            continue
        path = os.path.join(directory, f)
        manifest = load_manifest(path)
        if manifest is not None:
            step = int(manifest.get("step", -1))
        else:
            m = _STEP_RE.search(f)
            # mtime as a sub-second ordinal only breaks ties among
            # legacy files that encode no step at all
            step = int(m.group(1)) if m else -1
        out.append((step, path))
    out.sort(key=lambda t: (t[0], os.path.getmtime(t[1]), t[1]))
    return out


def latest(directory: str) -> str | None:
    """Newest checkpoint by *step* (manifest-ordered, never mtime)."""
    cands = _candidates(directory)
    return cands[-1][1] if cands else None


def latest_valid(directory: str, like: TrainState | None = None,
                 on_skip: Callable[[str, str], None] | None = None
                 ) -> str | None:
    """Newest checkpoint that passes full validation, walking backwards
    over corrupt/incomplete ones. ``on_skip(path, reason)`` observes each
    rejected candidate (the trainer logs these as recovery events)."""
    for step, path in reversed(_candidates(directory)):
        try:
            validate(path, like)
            return path
        except CheckpointCorruptError as e:
            if on_skip is not None:
                on_skip(path, str(e))
    return None


def _prune(directory: str, keep_last: int) -> None:
    """Delete all but the newest ``keep_last`` checkpoints (by step)."""
    for _, path in _candidates(directory)[:-keep_last]:
        for p in (path, manifest_path(path)):
            try:
                os.unlink(p)
            except OSError:
                pass
