"""Checkpointing: flat-leaf npz with path-keyed entries.

Works for any pytree of arrays (params, LARS momentum, step). Arrays are
gathered to host (fine at the scales this container runs; on a real pod
each host writes its own shard -- the path-keyed format is already
per-leaf, so sharded writes are a straightforward extension).
"""

from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np

from repro.train.state import TrainState

_SEP = "::"


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        out[key] = np.asarray(leaf)
    return out


def save(directory: str, state: TrainState, name: str = "ckpt") -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"{name}.npz")
    payload = {}
    for prefix, tree in (("params", state.params),
                         ("opt", state.opt_state)):
        for k, v in _flatten(tree).items():
            payload[f"{prefix}{_SEP}{k}"] = v
    payload["step"] = np.asarray(state.step)
    np.savez(path, **payload)
    return path


def restore(path: str, like: TrainState) -> TrainState:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        def fill(prefix, tree):
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            leaves = []
            for p, leaf in flat:
                key = prefix + _SEP + _SEP.join(
                    str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
                arr = data[key]
                if arr.shape != leaf.shape:
                    raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
                leaves.append(arr.astype(leaf.dtype))
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(tree), leaves)

        return TrainState(params=fill("params", like.params),
                          opt_state=fill("opt", like.opt_state),
                          step=jax.numpy.asarray(data["step"]))


def latest(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    cands = [f for f in os.listdir(directory) if f.endswith(".npz")]
    if not cands:
        return None
    cands.sort(key=lambda f: os.path.getmtime(os.path.join(directory, f)))
    return os.path.join(directory, cands[-1])
