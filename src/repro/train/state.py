"""Train state: fp32 master params + LARS momentum + step counter, plus the
dynamic loss-scale guard state (scale + clean-step counter) used by the
non-finite-gradient guard in ``trainer.make_train_step``."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lars


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array
    # reduced-precision guard state (docs/robustness.md): the loss is
    # multiplied by ``loss_scale`` before backward and the synced grads are
    # unscaled; the scale backs off on non-finite steps and regrows after
    # GuardConfig.growth_interval consecutive clean steps (``good_steps``).
    loss_scale: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.ones((), jnp.float32))
    good_steps: jax.Array = dataclasses.field(
        default_factory=lambda: jnp.zeros((), jnp.int32))

    @staticmethod
    def create(params, loss_scale: float = 1.0) -> "TrainState":
        return TrainState(params=params, opt_state=lars.init(params),
                          step=jnp.zeros((), jnp.int32),
                          loss_scale=jnp.asarray(loss_scale, jnp.float32),
                          good_steps=jnp.zeros((), jnp.int32))
