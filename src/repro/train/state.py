"""Train state: fp32 master params + LARS momentum + step counter."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import lars


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @staticmethod
    def create(params) -> "TrainState":
        return TrainState(params=params, opt_state=lars.init(params),
                          step=jnp.zeros((), jnp.int32))
