"""The distributed trainer: the paper's full recipe wired together,
hardened for faults (docs/robustness.md).

One ``train_step`` =
    shard_map over the data-parallel axes (model axis stays XLA-auto):
      1. local forward/backward in compute dtype (bf16; paper: fp16),
         loss multiplied by the dynamic loss scale
      2. gradient exchange with the configured strategy
         (2D-torus / ring / hierarchical / psum), bf16 buckets, fp32 for BN;
         ``TrainerConfig.grad_sync.bucket_bytes > 0`` splits the exchange
         into size-targeted buckets issued in reverse-backprop order so XLA
         overlaps each bucket with remaining backward compute
         (docs/gradient_sync.md)
      3. non-finite guard: an all-finite flag over the pmean'd loss and
         every synced gradient leaf gates the update -- params and momentum
         pass through unchanged on a non-finite step and the loss scale
         backs off (recovering after ``GuardConfig.growth_interval`` clean
         steps)
      4. LR + momentum from the schedule at the *fractional epoch*
      5. LARS update in fp32

The ``Trainer`` loops over the batch-size-control stages (paper §2.1) with
ONE step function (jit re-specializes per stage batch shape), retries
transient data failures with jittered exponential backoff
(``repro.utils.retry``), writes crash-consistent checkpoints periodically
and at stage boundaries -- by default *asynchronously*, off the training
thread (``checkpoint.AsyncCheckpointWriter``) -- resumes mid-stage from
the newest *valid* checkpoint, and degrades the grad-sync strategy
(torus2d -> ring -> psum) instead of aborting when the configured one
cannot run on the current mesh/jaxlib (or a torus axis is down).

``run`` itself is a **supervised recovery loop** (``repro.train.elastic``,
docs/robustness.md "Elastic recovery"): when the supervisor flags a
*permanent* failure mid-run -- a torus axis newly down, an unbroken streak
of guard-skipped steps, repeated step timeouts -- the trainer re-resolves
the sync strategy against the enlarged down-axis set, rebuilds the jitted
step for the degraded mesh, restores the newest valid checkpoint, and
re-enters the step loop in the same process; only an exhausted recovery
budget (or recovery without any checkpoint) aborts. Faults, including the
permanent classes, are injectable via ``repro.testing.chaos.FaultPlan``
for chaos testing.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import grad_sync as grad_sync_lib
from repro.core import lars as lars_lib
from repro.core import schedules as sched_lib
from repro.core.batch_control import TrainPlan, epoch_of
from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core.topology import TorusGrid, select_grid
from repro.obs import ObsConfig, Telemetry
from repro.obs.metrics import NULL_REGISTRY
from repro.obs.tracing import jax_profile
from repro.testing.chaos import RETRYABLE
from repro.train import checkpoint
from repro.train.elastic import ElasticConfig, PermanentFailure, Supervisor
from repro.train.state import TrainState
from repro.utils.retry import retry_call


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Non-finite-gradient guard + dynamic loss scale (paper trains in
    reduced precision; this is the standard overflow guard).

    Defaults are bf16-friendly (scale 1.0 -- bf16 shares fp32's exponent
    range, so scaling only matters after a fault); an fp16 run would start
    at ``init_scale=2**15``. With ``init_scale=1.0`` and no faults the
    guarded step is bit-identical to an unguarded one (multiply by exactly
    1.0, select-on-True), so enabling the guard costs no reproducibility.
    """

    enabled: bool = True
    init_scale: float = 1.0
    growth_interval: int = 200    # clean steps before the scale regrows
    growth_factor: float = 2.0
    backoff_factor: float = 0.5   # applied on every skipped step
    max_scale: float = 2.0 ** 15
    min_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    schedule: str = "B"                 # LR config A or B (paper Table 3)
    label_smoothing: float = 0.1
    grad_sync: GradSyncConfig = GradSyncConfig()
    lars: lars_lib.LARSConfig = lars_lib.LARSConfig()
    guard: GuardConfig = GuardConfig()
    aux_weight: float = 0.01            # MoE load-balance weight
    log_every: int = 10
    # fault tolerance (docs/robustness.md)
    ckpt_every_steps: int = 0           # 0: stage boundaries only
    ckpt_keep_last: int = 3
    ckpt_retries: int = 3
    ckpt_async: bool = True             # commit off the training thread
    ckpt_max_pending: int = 2           # async writer queue bound
    data_retries: int = 3
    retry_backoff_s: float = 0.05       # base of the exponential backoff
    elastic: ElasticConfig = ElasticConfig()  # mid-run recovery supervisor
    # observability (docs/observability.md): metrics JSONL / Chrome trace /
    # jax profiler paths; registry + tracer always run (near-zero cost)
    obs: ObsConfig = ObsConfig()


def make_train_step(loss_fn: Callable, mesh, dp_axes: tuple[str, ...],
                    cfg: TrainerConfig, grid: TorusGrid | None = None,
                    donate: bool = True):
    """Build the jitted step.

    ``loss_fn(params, batch, dp_axes) -> (loss, aux)`` computes the LOCAL
    (per-shard) mean loss; ``batch`` is the local shard inside shard_map.
    ``aux`` is an extra scalar loss term already locally averaged.

    The returned fn is batch-shape-polymorphic: jit re-specializes per
    stage shape, so ONE call to this builder serves every stage of a
    batch-size-control plan.
    """
    grid = grid or select_grid(dp_axes)
    schedule = sched_lib.make(cfg.schedule)
    guard = cfg.guard

    def step(state: TrainState, batch, epoch, global_batch):
        scale = state.loss_scale

        def total_loss(p):
            loss, aux = loss_fn(p, batch, dp_axes)
            tot = loss + cfg.aux_weight * aux
            if guard.enabled:
                tot = tot * scale.astype(tot.dtype)
            return tot, (loss, aux)

        (_, (loss, aux)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(state.params)
        grads = sync_tree(grads, grid, cfg.grad_sync)
        if guard.enabled:
            inv = 1.0 / scale   # exact for the power-of-two scales we use
            grads = jax.tree.map(lambda g: g * inv.astype(g.dtype), grads)

        loss_m = jax.lax.pmean(loss, dp_axes)
        # all-finite flag over loss + synced grads: the all-reduce already
        # propagated any shard's NaN/Inf to every shard, so the flag (and
        # the skip decision) is identical across the mesh.
        nonfinite = sum(
            jnp.sum(~jnp.isfinite(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
        finite = jnp.isfinite(loss_m) & (nonfinite == 0)

        lr = schedule.lr(epoch)
        mom = schedule.mom(epoch, global_batch)
        new_params, new_opt = lars_lib.update(
            state.params, grads, state.opt_state, lr=lr, momentum=mom,
            cfg=cfg.lars)

        if guard.enabled:
            # skip the update on non-finite steps: params/momentum pass
            # through unchanged (jnp.where selects bit-exactly on True)
            sel = functools.partial(jnp.where, finite)
            new_params = jax.tree.map(sel, new_params, state.params)
            new_opt = jax.tree.map(sel, new_opt, state.opt_state)
            good = jnp.where(finite, state.good_steps + 1, 0)
            grow = finite & (good >= guard.growth_interval)
            new_scale = jnp.where(
                finite,
                jnp.where(grow,
                          jnp.minimum(scale * guard.growth_factor,
                                      guard.max_scale),
                          scale),
                jnp.maximum(scale * guard.backoff_factor, guard.min_scale))
            good = jnp.where(grow, 0, good).astype(jnp.int32)
        else:
            new_scale, good = state.loss_scale, state.good_steps

        metrics = {
            "loss": loss_m,
            "aux": jax.lax.pmean(aux, dp_axes),
            "lr": lr, "momentum": mom,
            "grad_norm": jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))),
            "skipped": (~finite).astype(jnp.int32),
            "nonfinite_count": nonfinite.astype(jnp.int32),
            "loss_scale": new_scale,
        }
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               new_scale, good)
        return new_state, metrics

    # shard_map: manual over DP axes, auto over whatever else (model axis)
    manual = set(dp_axes)
    batch_spec = P(dp_axes)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(P(), batch_spec, P(), P()),
        out_specs=(P(), P()),
        axis_names=frozenset(manual), check_vma=False)
    return jax.jit(smapped, donate_argnums=(0,) if donate else ())


@dataclasses.dataclass
class Trainer:
    mesh: Any
    dp_axes: tuple[str, ...]
    loss_fn: Callable
    cfg: TrainerConfig
    plan: TrainPlan
    data_fn: Callable                  # (step_index, global_batch) -> batch
    checkpoint_dir: str | None = None
    fault_plan: Any | None = None      # repro.testing.chaos.FaultPlan
    telemetry: Any | None = None       # repro.obs.Telemetry; None: built
                                       # from cfg.obs and closed by run()

    def run(self, state: TrainState, max_steps: int | None = None,
            log: Callable = print, resume: bool = False):
        """Run the plan under elastic supervision. Returns
        ``(state, history)``.

        ``history`` holds per-step metric rows (every ``log_every`` steps,
        at stage ends, and on every skipped step) interleaved with event
        rows (grad-sync downgrades, data retries, checkpoint
        saves/recoveries, resume, ``elastic_failure`` /
        ``elastic_recovery``). Every row carries a ``"kind"`` marker --
        ``"metric"`` or ``"event"`` -- so a serialized history round-trips
        through JSONL unambiguously; rows are mirrored to the run's
        telemetry sink (``cfg.obs.metrics_path``) with per-step phase
        breakdowns and a final metrics summary (docs/observability.md).
        ``resume=True`` restores the newest *valid* checkpoint from
        ``checkpoint_dir`` and fast-forwards the plan to the exact
        mid-stage step.

        On a :class:`~repro.train.elastic.PermanentFailure` the loop
        re-resolves the sync strategy against the accumulated down axes,
        rebuilds the step fn, rolls back to the newest valid checkpoint,
        and continues in-process; after a recovery, step rows for the
        replayed span appear twice in ``history`` (pre- and post-rollback).
        """
        history: list[dict] = []
        cfg = self.cfg
        tel = self.telemetry
        own_tel = tel is None
        if own_tel:
            # one telemetry bundle per run; closed (summary row + trace
            # export) in the finally below. A caller-supplied telemetry is
            # left open -- the caller owns its lifecycle and run_id.
            tel = Telemetry(cfg.obs, meta={
                "source": "trainer", "schedule": cfg.schedule,
                "strategy": cfg.grad_sync.strategy,
                "bucket_bytes": cfg.grad_sync.bucket_bytes})

        def event(etype: str, **kw):
            history.append(tel.event(etype, **kw))
            log(f"[{etype}] " + " ".join(f"{k}={v}" for k, v in kw.items()))

        grid = select_grid(self.dp_axes)
        if self.fault_plan is None:
            initial_down: tuple[str, ...] = ()
        elif hasattr(self.fault_plan, "down_axes_at"):
            initial_down = tuple(self.fault_plan.down_axes_at(0))
        else:
            initial_down = tuple(getattr(self.fault_plan, "down_axes", ())
                                 or ())
        supervisor = Supervisor(cfg.elastic, initial_down_axes=initial_down,
                                metrics=tel.registry)

        writer = None
        if self.checkpoint_dir and cfg.ckpt_async:
            writer = checkpoint.AsyncCheckpointWriter(
                max_pending=cfg.ckpt_max_pending, retries=cfg.ckpt_retries,
                backoff_s=cfg.retry_backoff_s, metrics=tel.registry)

        data_fn = (self.fault_plan.wrap_data_fn(self.data_fn)
                   if self.fault_plan is not None else self.data_fn)

        try:
            start_step = 0
            if resume and self.checkpoint_dir:
                path = checkpoint.latest_valid(
                    self.checkpoint_dir, like=state,
                    on_skip=lambda p, reason: event(
                        "checkpoint_rejected", path=os.path.basename(p),
                        reason=reason))
                if path is not None:
                    state = checkpoint.restore(path, state)
                    start_step = int(state.step)
                    event("resume", path=os.path.basename(path),
                          step=start_step)

            # elastic recovery line: a permanent failure heals by rolling
            # back to a checkpoint, so commit one before the first
            # (buffer-donating) step consumes the initial state
            if (cfg.elastic.enabled and self.checkpoint_dir
                    and checkpoint.latest(self.checkpoint_dir) is None):
                self._save_checkpoint(state, None, event, writer,
                                      metrics=tel.registry)

            # -- supervised recovery loop (docs/robustness.md); optionally
            # under jax.profiler.trace so the device timeline (per-bucket
            # all-reduces overlapping backward) is captured alongside the
            # host spans (docs/observability.md)
            with jax_profile(cfg.obs.jax_profile_dir
                             if cfg.obs.enabled else None):
                while True:
                    context = ("startup" if supervisor.recoveries == 0
                               else "elastic")
                    # params_like: lets bucket_bytes="auto" tune against
                    # the real gradient structure (and re-tune for the
                    # degraded strategy after an elastic downgrade)
                    sync_cfg, sync_events = \
                        grad_sync_lib.resolve_sync_config(
                            cfg.grad_sync, grid, self.mesh, self.dp_axes,
                            down_axes=supervisor.down_axes, context=context,
                            params_like=state.params)
                    for ev in sync_events:
                        ev = dict(ev)
                        event(ev.pop("event"), **ev)
                    run_cfg = dataclasses.replace(cfg, grad_sync=sync_cfg)
                    # the bucket schedule is a host-side function of the
                    # param structure + resolved config: publish it as
                    # per-bucket gauges (re-published after a downgrade)
                    grad_sync_lib.record_bucket_metrics(
                        state.params, run_cfg.grad_sync, tel.registry)
                    # ONE step fn for every stage of this attempt: jit
                    # re-specializes per batch shape. (A per-global-batch
                    # cache here would store identical fns -- the builder
                    # never sees the batch size -- while hiding the
                    # per-stage recompile behind a dict hit.)
                    fn = make_train_step(self.loss_fn, self.mesh,
                                         self.dp_axes, run_cfg, grid=grid)
                    try:
                        state = self._run_steps(
                            fn, state, run_cfg, data_fn, start_step,
                            max_steps, supervisor, writer, history, event,
                            log, tel)
                        return state, history
                    except PermanentFailure as failure:
                        state, start_step = self._recover(
                            state, failure, supervisor, writer, event)
        finally:
            if writer is not None:
                writer.close()
                self._drain(writer, event)
            if own_tel:
                tel.close()

    # -- the per-attempt step loop ----------------------------------------

    def _run_steps(self, fn, state: TrainState, cfg: TrainerConfig, data_fn,
                   start_step: int, max_steps: int | None,
                   supervisor: Supervisor, writer, history: list, event,
                   log, tel) -> TrainState:
        """One supervised attempt over the plan; raises
        :class:`PermanentFailure` when the supervisor flags one.

        Each step runs inside a ``step`` span with ``data`` / ``dispatch`` /
        ``sync_wait`` / ``log`` / ``checkpoint`` children covering its full
        body, so the phase durations account for (nearly all of) the step's
        wall time -- docs/observability.md asserts the sum lands within 10%.
        """
        reg = tel.registry
        for stage in self.plan.stages:
            gb = stage.global_batch
            if start_step >= stage.first_step + stage.num_steps:
                continue       # fast-forward: stage fully covered by ckpt
            for i in range(stage.num_steps):
                gstep = stage.first_step + i
                if gstep < start_step:
                    continue   # fast-forward to the exact mid-stage step
                if max_steps is not None and gstep >= max_steps:
                    return state
                # pre-step health probe: a collective launched over a dead
                # axis wedges the mesh, so detection must win that race
                failure = supervisor.check_health(gstep, self.fault_plan)
                if failure is not None:
                    raise failure
                epoch = epoch_of(self.plan, stage, i)
                with tel.span("step", step=gstep) as sp_step:
                    with tel.span("data", step=gstep) as sp_data:
                        batch = self._fetch_batch(data_fn, gstep, gb, event)
                        if self.fault_plan is not None:
                            batch = self.fault_plan.corrupt_batch(gstep,
                                                                  batch)
                    t0 = time.monotonic()
                    with tel.span("dispatch", step=gstep) as sp_disp:
                        state, metrics = fn(state, batch,
                                            jnp.asarray(epoch, jnp.float32),
                                            jnp.asarray(gb, jnp.float32))
                    done = gstep + 1
                    # reading the flag forces a host sync; without the guard
                    # there is nothing to read and dispatch stays async
                    # (then elapsed_s covers dispatch only -- wall-clock
                    # timeout detection needs the guard's sync or injected
                    # signals)
                    with tel.span("sync_wait", step=gstep) as sp_sync:
                        skipped = (int(metrics["skipped"])
                                   if cfg.guard.enabled else 0)
                    elapsed = time.monotonic() - t0
                    timed_out = (
                        self.fault_plan is not None
                        and hasattr(self.fault_plan, "step_timed_out")
                        and self.fault_plan.step_timed_out(gstep))
                    with tel.span("log", step=gstep) as sp_log:
                        if (done % cfg.log_every == 0
                                or i == stage.num_steps - 1 or skipped):
                            m = {k: float(v) for k, v in metrics.items()}
                            m.update(
                                step=done, epoch=epoch, global_batch=gb,
                                skipped=skipped,
                                nonfinite_count=int(
                                    metrics["nonfinite_count"]),
                                kind="metric")
                            history.append(m)
                            tel.emit(m)
                            log(f"step {done:5d} epoch {epoch:6.2f} "
                                f"gb {gb:6d} loss {m['loss']:.4f} "
                                f"lr {m['lr']:.3f} mom {m['momentum']:.3f}"
                                + (f" SKIPPED "
                                   f"(nonfinite={m['nonfinite_count']}, "
                                   f"scale->{m['loss_scale']:g})"
                                   if skipped else ""))
                    # detection strictly precedes the periodic save: a
                    # failure here must not first persist a checkpoint whose
                    # step counter has advanced past the streak's skipped
                    # updates
                    failure = supervisor.observe_step(
                        gstep, skipped=bool(skipped), timed_out=timed_out,
                        elapsed_s=elapsed)
                    if failure is not None:
                        raise failure
                    with tel.span("checkpoint", step=gstep) as sp_ckpt:
                        if (self.checkpoint_dir and cfg.ckpt_every_steps
                                and done % cfg.ckpt_every_steps == 0
                                and supervisor.healthy):
                            self._save_checkpoint(state, stage, event,
                                                  writer,
                                                  metrics=tel.registry)
                        if writer is not None:
                            self._drain(writer, event)
                # host-side step accounting (outside the step span so the
                # recording cost is not inside what it measures)
                reg.histogram("step/wall_s").observe(sp_step.duration)
                reg.histogram("step/data_s").observe(sp_data.duration)
                reg.histogram("step/sync_wait_s").observe(sp_sync.duration)
                reg.counter("train/steps").inc()
                if cfg.guard.enabled:
                    if skipped:
                        reg.counter("train/skipped_steps").inc()
                        reg.counter("train/nonfinite_total").inc(
                            int(metrics["nonfinite_count"]))
                    reg.gauge("train/loss_scale").set(
                        float(metrics["loss_scale"]))
                if (tel.sink is not None
                        and done % max(1, cfg.obs.step_metrics_every) == 0):
                    tel.emit({
                        "kind": "metric", "metric": "step_phases",
                        "step": done, "wall_s": sp_step.duration,
                        "phases": {"data": sp_data.duration,
                                   "dispatch": sp_disp.duration,
                                   "sync_wait": sp_sync.duration,
                                   "log": sp_log.duration,
                                   "checkpoint": sp_ckpt.duration}})
            # stage-boundary save, unless the periodic save just covered it
            if self.checkpoint_dir and not (
                    cfg.ckpt_every_steps
                    and int(state.step) % cfg.ckpt_every_steps == 0):
                with tel.span("checkpoint", step=int(state.step)):
                    self._save_checkpoint(state, stage, event, writer,
                                          metrics=tel.registry)
        return state

    # -- recovery paths ---------------------------------------------------

    def _recover(self, state: TrainState, failure: PermanentFailure,
                 supervisor: Supervisor, writer, event
                 ) -> tuple[TrainState, int]:
        """Roll back past a permanent failure: flush in-flight saves, fold
        the failure into supervisor state, restore the newest valid
        checkpoint. Returns ``(state, start_step)`` for the next attempt;
        raises ``RuntimeError`` when recovery is impossible."""
        event("elastic_failure", kind=failure.kind, step=failure.step,
              down_axes=list(failure.down_axes), detail=failure.detail)
        if supervisor.exhausted:
            raise RuntimeError(
                f"elastic recovery budget exhausted "
                f"({supervisor.cfg.max_recoveries} recoveries) at step "
                f"{failure.step}: {failure.kind}") from failure
        if writer is not None:
            # durability barrier: every enqueued save must be committed (or
            # failed) before latest_valid decides where to roll back to
            writer.flush()
            self._drain(writer, event)
        attempt = supervisor.start_recovery(failure)
        path = None
        if self.checkpoint_dir:
            path = checkpoint.latest_valid(
                self.checkpoint_dir, like=state,
                on_skip=lambda p, reason: event(
                    "checkpoint_rejected", path=os.path.basename(p),
                    reason=reason))
        if path is None:
            raise RuntimeError(
                f"permanent failure at step {failure.step} "
                f"({failure.kind}) but no valid checkpoint to roll back "
                "to -- set checkpoint_dir to enable elastic recovery"
            ) from failure
        state = retry_call(
            lambda: checkpoint.restore(path, state),
            retries=self.cfg.ckpt_retries,
            backoff_s=self.cfg.retry_backoff_s, retry_on=(OSError,),
            seed=failure.step)
        start_step = int(state.step)
        event("elastic_recovery", attempt=attempt, step=start_step,
              path=os.path.basename(path),
              down_axes=list(supervisor.down_axes))
        return state, start_step

    def _fetch_batch(self, data_fn, gstep: int, gb: int, event):
        """Fetch with the shared jittered-backoff retry helper."""
        try:
            return retry_call(
                lambda: data_fn(gstep, gb),
                retries=self.cfg.data_retries,
                backoff_s=self.cfg.retry_backoff_s, retry_on=RETRYABLE,
                on_retry=lambda attempt, e: event(
                    "data_retry", step=gstep, attempt=attempt,
                    error=f"{type(e).__name__}: {e}"),
                seed=gstep)
        except RETRYABLE as e:
            raise RuntimeError(
                f"data_fn failed at step {gstep} after "
                f"{self.cfg.data_retries + 1} attempts") from e

    def _save_checkpoint(self, state: TrainState, stage, event,
                         writer=None, metrics=NULL_REGISTRY) -> None:
        """Crash-consistent save; a checkpoint failure is an event, not a
        training abort (the run continues from the previous checkpoint).
        With ``writer`` the commit runs off-thread (its own ``metrics``
        registry, given at construction) and its outcome events arrive via
        :meth:`_drain`."""
        hook = (self.fault_plan.checkpoint_io_hook
                if self.fault_plan is not None else None)
        meta = ({"stage_end_epoch": stage.stage.end_epoch,
                 "global_batch": stage.global_batch}
                if stage is not None else {"initial": True})
        if writer is not None:
            try:
                writer.save(self.checkpoint_dir, state,
                            keep_last=self.cfg.ckpt_keep_last, meta=meta,
                            io_hook=hook)
            except checkpoint.CheckpointError as e:
                event("checkpoint_failed", step=int(state.step),
                      error=str(e))
            return
        try:
            path = checkpoint.save(
                self.checkpoint_dir, state,
                retries=self.cfg.ckpt_retries,
                backoff_s=self.cfg.retry_backoff_s,
                keep_last=self.cfg.ckpt_keep_last,
                meta=meta, io_hook=hook, metrics=metrics,
                on_retry=lambda attempt, e: event(
                    "checkpoint_retry", step=int(state.step),
                    attempt=attempt, error=str(e)))
            event("checkpoint", step=int(state.step),
                  path=os.path.basename(path))
        except checkpoint.CheckpointError as e:
            event("checkpoint_failed", step=int(state.step), error=str(e))

    @staticmethod
    def _drain(writer, event) -> None:
        """Re-emit completed async-save outcomes as history events (on the
        training thread, keeping history single-writer)."""
        for ev in writer.drain_events():
            ev = dict(ev)
            event(ev.pop("event"), **ev)
