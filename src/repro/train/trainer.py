"""The distributed trainer: the paper's full recipe wired together,
hardened for faults (docs/robustness.md).

One ``train_step`` =
    shard_map over the data-parallel axes (model axis stays XLA-auto):
      1. local forward/backward in compute dtype (bf16; paper: fp16),
         loss multiplied by the dynamic loss scale
      2. gradient exchange with the configured strategy
         (2D-torus / ring / hierarchical / psum), bf16 buckets, fp32 for BN;
         ``TrainerConfig.grad_sync.bucket_bytes > 0`` splits the exchange
         into size-targeted buckets issued in reverse-backprop order so XLA
         overlaps each bucket with remaining backward compute
         (docs/gradient_sync.md)
      3. non-finite guard: an all-finite flag over the pmean'd loss and
         every synced gradient leaf gates the update -- params and momentum
         pass through unchanged on a non-finite step and the loss scale
         backs off (recovering after ``GuardConfig.growth_interval`` clean
         steps)
      4. LR + momentum from the schedule at the *fractional epoch*
      5. LARS update in fp32

The ``Trainer`` loops over the batch-size-control stages (paper §2.1) with
ONE step function (jit re-specializes per stage batch shape), retries
transient data failures with exponential backoff, writes crash-consistent
checkpoints periodically and at stage boundaries, resumes mid-stage from
the newest *valid* checkpoint, and degrades the grad-sync strategy
(torus2d -> ring -> psum) instead of aborting when the configured one
cannot run on the current mesh/jaxlib (or a torus axis is down). Faults
are injectable via ``repro.testing.chaos.FaultPlan`` for chaos testing.
"""

from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import grad_sync as grad_sync_lib
from repro.core import lars as lars_lib
from repro.core import schedules as sched_lib
from repro.core.batch_control import TrainPlan, epoch_of
from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core.topology import TorusGrid, select_grid
from repro.testing.chaos import RETRYABLE
from repro.train import checkpoint
from repro.train.state import TrainState


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Non-finite-gradient guard + dynamic loss scale (paper trains in
    reduced precision; this is the standard overflow guard).

    Defaults are bf16-friendly (scale 1.0 -- bf16 shares fp32's exponent
    range, so scaling only matters after a fault); an fp16 run would start
    at ``init_scale=2**15``. With ``init_scale=1.0`` and no faults the
    guarded step is bit-identical to an unguarded one (multiply by exactly
    1.0, select-on-True), so enabling the guard costs no reproducibility.
    """

    enabled: bool = True
    init_scale: float = 1.0
    growth_interval: int = 200    # clean steps before the scale regrows
    growth_factor: float = 2.0
    backoff_factor: float = 0.5   # applied on every skipped step
    max_scale: float = 2.0 ** 15
    min_scale: float = 1.0


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    schedule: str = "B"                 # LR config A or B (paper Table 3)
    label_smoothing: float = 0.1
    grad_sync: GradSyncConfig = GradSyncConfig()
    lars: lars_lib.LARSConfig = lars_lib.LARSConfig()
    guard: GuardConfig = GuardConfig()
    aux_weight: float = 0.01            # MoE load-balance weight
    log_every: int = 10
    # fault tolerance (docs/robustness.md)
    ckpt_every_steps: int = 0           # 0: stage boundaries only
    ckpt_keep_last: int = 3
    ckpt_retries: int = 3
    data_retries: int = 3
    retry_backoff_s: float = 0.05       # base of the exponential backoff


def make_train_step(loss_fn: Callable, mesh, dp_axes: tuple[str, ...],
                    cfg: TrainerConfig, grid: TorusGrid | None = None,
                    donate: bool = True):
    """Build the jitted step.

    ``loss_fn(params, batch, dp_axes) -> (loss, aux)`` computes the LOCAL
    (per-shard) mean loss; ``batch`` is the local shard inside shard_map.
    ``aux`` is an extra scalar loss term already locally averaged.

    The returned fn is batch-shape-polymorphic: jit re-specializes per
    stage shape, so ONE call to this builder serves every stage of a
    batch-size-control plan.
    """
    grid = grid or select_grid(dp_axes)
    schedule = sched_lib.make(cfg.schedule)
    guard = cfg.guard

    def step(state: TrainState, batch, epoch, global_batch):
        scale = state.loss_scale

        def total_loss(p):
            loss, aux = loss_fn(p, batch, dp_axes)
            tot = loss + cfg.aux_weight * aux
            if guard.enabled:
                tot = tot * scale.astype(tot.dtype)
            return tot, (loss, aux)

        (_, (loss, aux)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(state.params)
        grads = sync_tree(grads, grid, cfg.grad_sync)
        if guard.enabled:
            inv = 1.0 / scale   # exact for the power-of-two scales we use
            grads = jax.tree.map(lambda g: g * inv.astype(g.dtype), grads)

        loss_m = jax.lax.pmean(loss, dp_axes)
        # all-finite flag over loss + synced grads: the all-reduce already
        # propagated any shard's NaN/Inf to every shard, so the flag (and
        # the skip decision) is identical across the mesh.
        nonfinite = sum(
            jnp.sum(~jnp.isfinite(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
        finite = jnp.isfinite(loss_m) & (nonfinite == 0)

        lr = schedule.lr(epoch)
        mom = schedule.mom(epoch, global_batch)
        new_params, new_opt = lars_lib.update(
            state.params, grads, state.opt_state, lr=lr, momentum=mom,
            cfg=cfg.lars)

        if guard.enabled:
            # skip the update on non-finite steps: params/momentum pass
            # through unchanged (jnp.where selects bit-exactly on True)
            sel = functools.partial(jnp.where, finite)
            new_params = jax.tree.map(sel, new_params, state.params)
            new_opt = jax.tree.map(sel, new_opt, state.opt_state)
            good = jnp.where(finite, state.good_steps + 1, 0)
            grow = finite & (good >= guard.growth_interval)
            new_scale = jnp.where(
                finite,
                jnp.where(grow,
                          jnp.minimum(scale * guard.growth_factor,
                                      guard.max_scale),
                          scale),
                jnp.maximum(scale * guard.backoff_factor, guard.min_scale))
            good = jnp.where(grow, 0, good).astype(jnp.int32)
        else:
            new_scale, good = state.loss_scale, state.good_steps

        metrics = {
            "loss": loss_m,
            "aux": jax.lax.pmean(aux, dp_axes),
            "lr": lr, "momentum": mom,
            "grad_norm": jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))),
            "skipped": (~finite).astype(jnp.int32),
            "nonfinite_count": nonfinite.astype(jnp.int32),
            "loss_scale": new_scale,
        }
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               new_scale, good)
        return new_state, metrics

    # shard_map: manual over DP axes, auto over whatever else (model axis)
    manual = set(dp_axes)
    batch_spec = P(dp_axes)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(P(), batch_spec, P(), P()),
        out_specs=(P(), P()),
        axis_names=frozenset(manual), check_vma=False)
    return jax.jit(smapped, donate_argnums=(0,) if donate else ())


@dataclasses.dataclass
class Trainer:
    mesh: Any
    dp_axes: tuple[str, ...]
    loss_fn: Callable
    cfg: TrainerConfig
    plan: TrainPlan
    data_fn: Callable                  # (step_index, global_batch) -> batch
    checkpoint_dir: str | None = None
    fault_plan: Any | None = None      # repro.testing.chaos.FaultPlan

    def run(self, state: TrainState, max_steps: int | None = None,
            log: Callable = print, resume: bool = False):
        """Run the plan. Returns ``(state, history)``.

        ``history`` holds per-step metric rows (every ``log_every`` steps,
        at stage ends, and on every skipped step) interleaved with event
        rows (``{"event": ...}``: grad-sync downgrades, data retries,
        checkpoint saves/recoveries, resume). ``resume=True`` restores the
        newest *valid* checkpoint from ``checkpoint_dir`` and fast-forwards
        the plan to the exact mid-stage step.
        """
        history: list[dict] = []

        def event(kind: str, **kw):
            rec = {"event": kind, **kw}
            history.append(rec)
            log(f"[{kind}] " + " ".join(f"{k}={v}" for k, v in kw.items()))

        # -- graceful grad-sync degradation (docs/robustness.md) ----------
        grid = select_grid(self.dp_axes)
        down = tuple(getattr(self.fault_plan, "down_axes", ()) or ())
        sync_cfg, sync_events = grad_sync_lib.resolve_sync_config(
            self.cfg.grad_sync, grid, self.mesh, self.dp_axes,
            down_axes=down)
        for ev in sync_events:
            ev = dict(ev)
            event(ev.pop("event"), **ev)
        cfg = dataclasses.replace(self.cfg, grad_sync=sync_cfg)

        # ONE step fn for every stage: jit re-specializes per batch shape.
        # (A per-global-batch cache here would store identical fns -- the
        # builder never sees the batch size -- while hiding the per-stage
        # recompile behind a dict hit.)
        fn = make_train_step(self.loss_fn, self.mesh, self.dp_axes, cfg,
                             grid=grid)

        start_step = 0
        if resume and self.checkpoint_dir:
            path = checkpoint.latest_valid(
                self.checkpoint_dir, like=state,
                on_skip=lambda p, reason: event(
                    "checkpoint_rejected", path=os.path.basename(p),
                    reason=reason))
            if path is not None:
                state = checkpoint.restore(path, state)
                start_step = int(state.step)
                event("resume", path=os.path.basename(path),
                      step=start_step)

        data_fn = (self.fault_plan.wrap_data_fn(self.data_fn)
                   if self.fault_plan is not None else self.data_fn)

        for stage in self.plan.stages:
            gb = stage.global_batch
            if start_step >= stage.first_step + stage.num_steps:
                continue       # fast-forward: stage fully covered by ckpt
            for i in range(stage.num_steps):
                gstep = stage.first_step + i
                if gstep < start_step:
                    continue   # fast-forward to the exact mid-stage step
                if max_steps is not None and gstep >= max_steps:
                    return state, history
                epoch = epoch_of(self.plan, stage, i)
                batch = self._fetch_batch(data_fn, gstep, gb, event)
                if self.fault_plan is not None:
                    batch = self.fault_plan.corrupt_batch(gstep, batch)
                state, metrics = fn(state, batch,
                                    jnp.asarray(epoch, jnp.float32),
                                    jnp.asarray(gb, jnp.float32))
                done = gstep + 1
                # reading the flag forces a host sync; without the guard
                # there is nothing to read and dispatch stays async
                skipped = int(metrics["skipped"]) if cfg.guard.enabled else 0
                if (done % cfg.log_every == 0 or i == stage.num_steps - 1
                        or skipped):
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=done, epoch=epoch, global_batch=gb,
                             skipped=skipped,
                             nonfinite_count=int(metrics["nonfinite_count"]))
                    history.append(m)
                    log(f"step {done:5d} epoch {epoch:6.2f} gb {gb:6d} "
                        f"loss {m['loss']:.4f} lr {m['lr']:.3f} "
                        f"mom {m['momentum']:.3f}"
                        + (f" SKIPPED (nonfinite={m['nonfinite_count']}, "
                           f"scale->{m['loss_scale']:g})" if skipped else ""))
                if (self.checkpoint_dir and cfg.ckpt_every_steps
                        and done % cfg.ckpt_every_steps == 0):
                    self._save_checkpoint(state, stage, event)
            # stage-boundary save, unless the periodic save just covered it
            if self.checkpoint_dir and not (
                    cfg.ckpt_every_steps
                    and int(state.step) % cfg.ckpt_every_steps == 0):
                self._save_checkpoint(state, stage, event)
        return state, history

    # -- recovery paths ---------------------------------------------------

    def _fetch_batch(self, data_fn, gstep: int, gb: int, event):
        """Fetch with retry + exponential backoff on transient failures."""
        delay = self.cfg.retry_backoff_s
        last: Exception | None = None
        for attempt in range(self.cfg.data_retries + 1):
            try:
                return data_fn(gstep, gb)
            except RETRYABLE as e:
                last = e
                event("data_retry", step=gstep, attempt=attempt,
                      error=f"{type(e).__name__}: {e}")
                if attempt < self.cfg.data_retries:
                    time.sleep(delay)
                    delay *= 2
        raise RuntimeError(
            f"data_fn failed at step {gstep} after "
            f"{self.cfg.data_retries + 1} attempts") from last

    def _save_checkpoint(self, state: TrainState, stage, event) -> None:
        """Crash-consistent save; a checkpoint failure is an event, not a
        training abort (the run continues from the previous checkpoint)."""
        hook = (self.fault_plan.checkpoint_io_hook
                if self.fault_plan is not None else None)
        meta = {"stage_end_epoch": stage.stage.end_epoch,
                "global_batch": stage.global_batch}
        try:
            path = checkpoint.save(
                self.checkpoint_dir, state,
                retries=self.cfg.ckpt_retries,
                backoff_s=self.cfg.retry_backoff_s,
                keep_last=self.cfg.ckpt_keep_last,
                meta=meta, io_hook=hook,
                on_retry=lambda attempt, e: event(
                    "checkpoint_retry", step=int(state.step),
                    attempt=attempt, error=str(e)))
            event("checkpoint", step=int(state.step),
                  path=os.path.basename(path))
        except checkpoint.CheckpointError as e:
            event("checkpoint_failed", step=int(state.step), error=str(e))
