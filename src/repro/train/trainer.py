"""The distributed trainer: the paper's full recipe wired together.

One ``train_step`` =
    shard_map over the data-parallel axes (model axis stays XLA-auto):
      1. local forward/backward in compute dtype (bf16; paper: fp16)
      2. gradient exchange with the configured strategy
         (2D-torus / ring / hierarchical / psum), bf16 buckets, fp32 for BN;
         ``TrainerConfig.grad_sync.bucket_bytes > 0`` splits the exchange
         into size-targeted buckets issued in reverse-backprop order so XLA
         overlaps each bucket with remaining backward compute
         (docs/gradient_sync.md)
      3. LR + momentum from the schedule at the *fractional epoch*
      4. LARS update in fp32

The ``Trainer`` loops over the batch-size-control stages (paper §2.1),
jitting one step per stage shape, and checkpoints at stage boundaries.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core import lars as lars_lib
from repro.core import schedules as sched_lib
from repro.core.batch_control import TrainPlan, build_plan, epoch_of
from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core.topology import TorusGrid, select_grid
from repro.train.state import TrainState


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    schedule: str = "B"                 # LR config A or B (paper Table 3)
    label_smoothing: float = 0.1
    grad_sync: GradSyncConfig = GradSyncConfig()
    lars: lars_lib.LARSConfig = lars_lib.LARSConfig()
    aux_weight: float = 0.01            # MoE load-balance weight
    log_every: int = 10


def make_train_step(loss_fn: Callable, mesh, dp_axes: tuple[str, ...],
                    cfg: TrainerConfig, grid: TorusGrid | None = None,
                    donate: bool = True):
    """Build the jitted step.

    ``loss_fn(params, batch, dp_axes) -> (loss, aux)`` computes the LOCAL
    (per-shard) mean loss; ``batch`` is the local shard inside shard_map.
    ``aux`` is an extra scalar loss term already locally averaged.
    """
    grid = grid or select_grid(dp_axes)
    schedule = sched_lib.make(cfg.schedule)

    def step(state: TrainState, batch, epoch, global_batch):
        def total_loss(p):
            loss, aux = loss_fn(p, batch, dp_axes)
            return loss + cfg.aux_weight * aux, (loss, aux)

        (tot, (loss, aux)), grads = jax.value_and_grad(
            total_loss, has_aux=True)(state.params)
        grads = sync_tree(grads, grid, cfg.grad_sync)
        lr = schedule.lr(epoch)
        mom = schedule.mom(epoch, global_batch)
        new_params, new_opt = lars_lib.update(
            state.params, grads, state.opt_state, lr=lr, momentum=mom,
            cfg=cfg.lars)
        metrics = {
            "loss": jax.lax.pmean(loss, dp_axes),
            "aux": jax.lax.pmean(aux, dp_axes),
            "lr": lr, "momentum": mom,
            "grad_norm": jnp.sqrt(sum(
                jnp.sum(g.astype(jnp.float32) ** 2)
                for g in jax.tree.leaves(grads))),
        }
        return TrainState(new_params, new_opt, state.step + 1), metrics

    # shard_map: manual over DP axes, auto over whatever else (model axis)
    manual = set(dp_axes)
    batch_spec = P(dp_axes)
    smapped = shard_map(
        step, mesh=mesh,
        in_specs=(P(), batch_spec, P(), P()),
        out_specs=(P(), P()),
        axis_names=frozenset(manual), check_vma=False)
    return jax.jit(smapped, donate_argnums=(0,) if donate else ())


@dataclasses.dataclass
class Trainer:
    mesh: Any
    dp_axes: tuple[str, ...]
    loss_fn: Callable
    cfg: TrainerConfig
    plan: TrainPlan
    data_fn: Callable                  # (step_index, global_batch) -> batch
    checkpoint_dir: str | None = None

    def run(self, state: TrainState, max_steps: int | None = None,
            log: Callable = print):
        history = []
        step_fns = {}
        total = 0
        for stage in self.plan.stages:
            gb = stage.global_batch
            if gb not in step_fns:
                step_fns[gb] = make_train_step(
                    self.loss_fn, self.mesh, self.dp_axes, self.cfg)
            fn = step_fns[gb]
            for i in range(stage.num_steps):
                if max_steps is not None and total >= max_steps:
                    return state, history
                epoch = epoch_of(self.plan, stage, i)
                batch = self.data_fn(stage.first_step + i, gb)
                state, metrics = fn(state, batch,
                                    jnp.asarray(epoch, jnp.float32),
                                    jnp.asarray(gb, jnp.float32))
                total += 1
                if total % self.cfg.log_every == 0 or i == stage.num_steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m.update(step=total, epoch=epoch, global_batch=gb)
                    history.append(m)
                    log(f"step {total:5d} epoch {epoch:6.2f} gb {gb:6d} "
                        f"loss {m['loss']:.4f} lr {m['lr']:.3f} "
                        f"mom {m['momentum']:.3f}")
            if self.checkpoint_dir:
                from repro.train import checkpoint
                checkpoint.save(self.checkpoint_dir, state,
                                name=f"stage_e{stage.stage.end_epoch:g}")
        return state, history
