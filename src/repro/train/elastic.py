"""Elastic self-healing: permanent-failure detection + recovery supervision.

PR 7's fault layer handles *transient* faults (a NaN step, a flaky data
read, a crashed checkpoint write) and *startup-time* degradation (a torus
axis already down when the job launches). This module handles the remaining
class: the hardware degrades **mid-run** -- a torus link dies at step k, a
node starts emitting garbage gradients, steps begin timing out -- and the
job must finish anyway, on the degraded mesh, without a process restart.

The split of responsibilities (docs/robustness.md, "Elastic recovery"):

* :class:`Supervisor` (this module) is pure bookkeeping: it accumulates
  health signals per step, decides when a fault pattern is *permanent*
  (vs. the transient blips the in-step guard already absorbs), and tracks
  the accumulated set of down axes plus the recovery budget. It raises
  nothing and touches no jax state -- fully unit-testable.
* ``Trainer.run`` owns the actual recovery loop: on a
  :class:`PermanentFailure` it flushes the async checkpoint writer,
  re-resolves the grad-sync strategy via ``resolve_sync_config`` with the
  enlarged down-axis set (emitting a mid-run ``grad_sync_downgrade``
  event), rebuilds the jitted train step for the degraded mesh, restores
  from the newest valid checkpoint, and re-enters the step loop.

Permanence heuristics (all thresholds in :class:`ElasticConfig`):

* **axis down** -- a mesh axis newly reported dead by the health source
  (``FaultPlan.down_axes_at`` in tests; a real deployment plugs its
  heartbeat monitor into the same trainer hook). One report is permanent:
  links do not resurrect mid-run.
* **non-finite streak** -- the in-step guard skipping
  ``max_consecutive_nonfinite`` steps in a row. Isolated overflows are the
  guard's job (backoff + skip); an unbroken streak means the loss scale
  cannot save us (sick node, corrupted weights) and only a rollback can.
* **timeout streak** -- ``max_consecutive_timeouts`` consecutive steps
  over ``step_timeout_s`` wall-clock (or injected timeout signals): a
  straggler that never recovers is a dead worker with extra steps.
"""

from __future__ import annotations

import dataclasses

from repro.obs.metrics import NULL_REGISTRY


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Thresholds separating transient faults from permanent failures."""

    enabled: bool = True
    #: consecutive guard-skipped steps before the numeric fault is treated
    #: as permanent (rollback instead of more loss-scale backoff)
    max_consecutive_nonfinite: int = 8
    #: consecutive timed-out steps before the straggler is treated as dead
    max_consecutive_timeouts: int = 3
    #: wall-clock budget per step; None disables clock-based detection
    #: (injected FaultPlan timeout signals still count)
    step_timeout_s: float | None = None
    #: recovery attempts before the supervisor gives up and aborts
    max_recoveries: int = 3


class PermanentFailure(RuntimeError):
    """A fault pattern the in-step/transient machinery cannot absorb.

    Raised by ``Trainer`` when the :class:`Supervisor` reports one; carries
    everything the recovery path needs (and everything the
    ``elastic_failure`` history event records).
    """

    def __init__(self, kind: str, step: int,
                 down_axes: tuple[str, ...] = (), detail: str = ""):
        super().__init__(
            f"permanent failure at step {step}: {kind}"
            + (f" (axes {list(down_axes)})" if down_axes else "")
            + (f" -- {detail}" if detail else ""))
        self.kind = kind
        self.step = step
        self.down_axes = tuple(down_axes)
        self.detail = detail


class Supervisor:
    """Accumulates per-step health signals and the recovery budget.

    One instance supervises one ``Trainer.run`` call across all of its
    recovery attempts; streak counters reset on recovery (the rollback
    changed the world), the down-axis set and recovery count only grow.

    ``metrics`` (repro.obs.metrics registry) mirrors the bookkeeping as
    ``elastic/*`` counters and gauges (docs/observability.md) so a run's
    health history survives in the metrics JSONL summary -- the CI chaos
    smoke gates on ``elastic/recoveries`` being nonzero under injected
    faults and zero fault-free.
    """

    def __init__(self, cfg: ElasticConfig,
                 initial_down_axes: tuple[str, ...] = (),
                 metrics=NULL_REGISTRY):
        self.cfg = cfg
        self._down: set[str] = set(initial_down_axes)
        self.recoveries = 0
        self._nonfinite_streak = 0
        self._timeout_streak = 0
        self._metrics = metrics
        metrics.gauge("elastic/down_axes").set(len(self._down))

    @property
    def down_axes(self) -> tuple[str, ...]:
        return tuple(sorted(self._down))

    @property
    def exhausted(self) -> bool:
        return self.recoveries >= self.cfg.max_recoveries

    @property
    def healthy(self) -> bool:
        """No fault streak in progress. The trainer only takes *periodic*
        checkpoints of healthy states: a checkpoint stamped mid-streak
        carries a step counter past updates that were skipped, so rolling
        back to it would silently drop them."""
        return self._nonfinite_streak == 0 and self._timeout_streak == 0

    # -- detection ---------------------------------------------------------

    def check_health(self, step: int, fault_plan) -> PermanentFailure | None:
        """Pre-step health probe: any mesh axis newly reported down?

        Runs *before* the step is dispatched -- launching a collective over
        a dead axis wedges the whole mesh, so the probe must win the race.
        """
        if not self.cfg.enabled or fault_plan is None:
            return None
        probe = getattr(fault_plan, "down_axes_at", None)
        if probe is None:
            return None
        new = set(probe(step)) - self._down
        if new:
            self._metrics.counter("elastic/permanent_failures").inc()
            return PermanentFailure(
                "axis_down", step, down_axes=tuple(sorted(new)),
                detail="health probe reports torus axis(es) dead")
        return None

    def observe_step(self, step: int, *, skipped: bool,
                     timed_out: bool = False,
                     elapsed_s: float | None = None
                     ) -> PermanentFailure | None:
        """Post-step signal intake; returns a failure once a streak crosses
        its permanence threshold."""
        if not self.cfg.enabled:
            return None
        self._nonfinite_streak = self._nonfinite_streak + 1 if skipped else 0
        if skipped:
            self._metrics.counter("elastic/skipped_steps").inc()
        if self.cfg.step_timeout_s is not None and elapsed_s is not None \
                and elapsed_s > self.cfg.step_timeout_s:
            timed_out = True
        self._timeout_streak = self._timeout_streak + 1 if timed_out else 0
        if timed_out:
            self._metrics.counter("elastic/timeout_steps").inc()
        if self._nonfinite_streak >= self.cfg.max_consecutive_nonfinite:
            self._metrics.counter("elastic/permanent_failures").inc()
            return PermanentFailure(
                "nonfinite_streak", step,
                detail=f"{self._nonfinite_streak} consecutive guard-skipped "
                       "steps; loss-scale backoff cannot recover this")
        if self._timeout_streak >= self.cfg.max_consecutive_timeouts:
            self._metrics.counter("elastic/permanent_failures").inc()
            return PermanentFailure(
                "timeout", step,
                detail=f"{self._timeout_streak} consecutive step timeouts")
        return None

    # -- recovery bookkeeping ---------------------------------------------

    def start_recovery(self, failure: PermanentFailure) -> int:
        """Fold the failure into supervisor state; returns the attempt
        number (1-based). Caller must have checked ``exhausted`` first."""
        self._down |= set(failure.down_axes)
        self._nonfinite_streak = 0
        self._timeout_streak = 0
        self.recoveries += 1
        self._metrics.counter("elastic/recoveries").inc()
        self._metrics.gauge("elastic/down_axes").set(len(self._down))
        return self.recoveries
