"""Version-compat shims for JAX APIs that moved between releases.

``shard_map`` has lived in three places / signatures:

* jax >= 0.6:   ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
                axis_names=<manual axes>, check_vma=...)``
* jax 0.4/0.5:  ``jax.experimental.shard_map.shard_map(f, mesh, in_specs,
                out_specs, check_rep=..., auto=<NON-manual axes>)``

The repo is written against the new keyword surface (``axis_names`` names
the *manual* axes, ``check_vma`` replaces ``check_rep``); this module maps
those keywords onto whichever implementation the installed jax provides, so
every caller does ``from repro.compat import shard_map`` and nothing else.
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax

_new_shard_map = getattr(jax, "shard_map", None)
if _new_shard_map is None:
    from jax.experimental.shard_map import shard_map as _old_shard_map
else:
    _old_shard_map = None


# Inside a *partial*-manual shard_map (some mesh axes auto, e.g. the model
# axis on the non-FSDP train path), the old-API jaxlib SPMD partitioner
# hard-aborts ("Check failed: ...IsManualSubgroup()", an F-level check that
# kills the process) on psum_scatter / all_gather / ppermute -- and on the
# transformer fwd/bwd graph itself once auto-sharded params flow through.
# Fully-manual shard_map (every mesh axis manual -- the whole test suite and
# the pure-DP trainer) is unaffected. Callers that mix auto axes must gate
# on this flag and degrade/skip when it is False (see launch/dryrun.py).
SUPPORTS_PARTIAL_MANUAL_COLLECTIVES = _new_shard_map is not None


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict across jax versions.

    jax <= 0.4 returns a list with one properties-dict per partition; newer
    jax returns the dict directly.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def axis_size(axis_name: Any) -> int:
    """``lax.axis_size`` resolved across jax versions.

    Older jax has no ``lax.axis_size``; there ``lax.psum(1, axis)`` is
    constant-folded to a static python int at trace time, which is exactly
    the named-axis size. Accepts a single axis name or a tuple (product).
    """
    from jax import lax
    if hasattr(lax, "axis_size"):
        if isinstance(axis_name, (tuple, list)):
            size = 1
            for a in axis_name:
                size *= lax.axis_size(a)
            return size
        return lax.axis_size(axis_name)
    return lax.psum(1, axis_name)


def shard_map(f: Callable | None = None, *, mesh: Any, in_specs: Any,
              out_specs: Any, axis_names: Any = None,
              check_vma: bool | None = None,
              check_rep: bool | None = None) -> Callable:
    """``jax.shard_map`` resolved across jax versions.

    ``axis_names`` is the set of mesh axes to treat as manual (omit for all
    axes manual); ``check_vma``/``check_rep`` are accepted interchangeably.
    Usable as ``shard_map(f, mesh=..., ...)`` or via ``functools.partial``
    with ``f`` omitted (decorator style).
    """
    if f is None:
        return functools.partial(
            shard_map, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=check_vma, check_rep=check_rep)

    check = check_vma if check_vma is not None else check_rep

    if _new_shard_map is not None:
        kwargs = {}
        if axis_names is not None:
            kwargs["axis_names"] = frozenset(axis_names)
        if check is not None:
            kwargs["check_vma"] = check
        return _new_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)

    # old API: `auto` is the complement of the manual axes on the mesh
    kwargs = {}
    if axis_names is not None:
        kwargs["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    if check is not None:
        kwargs["check_rep"] = check
    return _old_shard_map(f, mesh, in_specs, out_specs, **kwargs)
