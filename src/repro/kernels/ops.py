"""Jit-ready wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode so
every test validates the exact kernel body; on TPU the same call compiles
to Mosaic. ``INTERPRET`` flips automatically off-TPU.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels import lars_update as _lars
from repro.kernels import ls_xent as _lsx

INTERPRET = jax.default_backend() != "tpu"


def lars_update(p, g, v, *, lr, mom, eta, weight_decay, eps,
                interpret: bool | None = None):
    """Fused LARS step; norms computed outside (tiny XLA reductions)."""
    interpret = INTERPRET if interpret is None else interpret
    p32 = p.astype(jnp.float32)
    g32 = g.astype(jnp.float32)
    w_norm = jnp.linalg.norm(p32)
    g_norm = jnp.linalg.norm(g32)
    trust = jnp.where((w_norm > 0) & (g_norm > 0),
                      eta * w_norm / (g_norm + weight_decay * w_norm + eps),
                      1.0)
    return _lars.lars_update_pallas(
        p32, g32, v, trust_lr=trust * lr, mom=mom,
        weight_decay=weight_decay, interpret=interpret)


def ls_xent(logits, labels, *, smoothing: float,
            interpret: bool | None = None):
    """Per-row label-smoothed cross-entropy, fused over the vocab dim."""
    interpret = INTERPRET if interpret is None else interpret
    return _lsx.ls_xent_pallas(logits, labels, smoothing=smoothing,
                               interpret=interpret)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, interpret: bool | None = None):
    """Flash attention fwd (TPU kernel; HBM traffic O(S*D) not O(S^2))."""
    from repro.kernels import flash_attn as _fa
    interpret = INTERPRET if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap, scale=scale,
                               interpret=interpret)
