"""Fused LARS update kernel (Pallas, TPU target).

The paper runs LARS in fp32 (§3.2) over every parameter tensor each step.
Unfused, XLA materializes g + wd*p, then mom*v + ..., then p - v: ~5 HBM
round-trips over 3 tensors. This kernel does the elementwise part in ONE
pass per tile: read (p, g, v), write (p', v').

The trust ratio needs global ||p||, ||g|| -- those are tiny reductions
computed outside (one fused XLA reduction each) and passed as scalars via
scalar-prefetch-like (1,1) SMEM operands; the kernel body is pure VMEM
elementwise work, MXU-free, aligned to (8, 128) fp32 VREG tiles.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lars_kernel(scal_ref, p_ref, g_ref, v_ref, p_out, v_out):
    """scal_ref: (4,) fp32 = [trust*lr, mom, wd, unused]."""
    tl = scal_ref[0]
    mom = scal_ref[1]
    wd = scal_ref[2]
    p = p_ref[...]
    g = g_ref[...]
    v = v_ref[...]
    v_new = mom * v + tl * (g + wd * p)
    p_out[...] = p - v_new
    v_out[...] = v_new


def lars_update_pallas(p, g, v, *, trust_lr, mom, weight_decay,
                       block_rows: int = 256, interpret: bool = False):
    """p/g/v: fp32 tensors of identical shape (flattened to 2D tiles).

    trust_lr may be a traced scalar (trust * lr).
    """
    orig_shape = p.shape
    n = p.size
    # pad the flat view to (rows, 128) fp32 lanes
    lane = 128
    rows = -(-n // lane)
    pad = rows * lane - n

    def flat(x):
        x = jnp.ravel(x).astype(jnp.float32)
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
        return x.reshape(rows, lane)

    pf, gf, vf = flat(p), flat(g), flat(v)
    scal = jnp.stack([jnp.asarray(trust_lr, jnp.float32),
                      jnp.asarray(mom, jnp.float32),
                      jnp.asarray(weight_decay, jnp.float32),
                      jnp.zeros((), jnp.float32)])

    br = min(block_rows, rows)
    grid = (-(-rows // br),)
    tile = pl.BlockSpec((br, lane), lambda i: (i, 0))
    p_new, v_new = pl.pallas_call(
        _lars_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((4,), lambda i: (0,)),
                  tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((rows, lane), jnp.float32),
                   jax.ShapeDtypeStruct((rows, lane), jnp.float32)],
        interpret=interpret,
    )(scal, pf, gf, vf)

    def unflat(x):
        x = x.reshape(-1)
        if pad:
            x = x[:n]
        return x.reshape(orig_shape)

    return unflat(p_new), unflat(v_new)
