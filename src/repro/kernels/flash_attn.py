"""Flash attention forward kernel (Pallas, TPU target).

The roofline analysis (EXPERIMENTS.md §Roofline) shows prefill/train
steps are MEMORY-dominated for every attention arch: the chunked-jnp
attention materializes (q_chunk, S) fp32 logits + softmax weights per
layer in HBM. This kernel keeps the running max / sum / output accumulator
in VMEM across k-blocks (online softmax), so per (q-block, k-block) tile
only the (bq, bk) logits live in VMEM and logits NEVER touch HBM:
HBM traffic drops from O(S^2) to O(S * D) per head.

Layout: q/k/v as (B, H, S, D) (heads-major so a (b, h) pair is a grid
row); GQA is handled by the wrapper (kv head index = h // group). Causal
+ sliding-window masking inside the kernel; k-blocks entirely above the
diagonal are masked to -inf (the index map still visits them -- Pallas
grids are dense -- but they contribute exp(-inf)=0; a production version
would use a data-dependent grid).

MXU alignment: block_q x D and block_k x D tiles with D in {64, 128, 256};
block sizes default to 128 (fp32 VREG/MXU friendly).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq, bk, nk, scale, causal, window, softcap):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32)                      # (bq, D)
    k = k_ref[0].astype(jnp.float32)                      # (bk, D)
    v = v_ref[0].astype(jnp.float32)

    s = (q * scale) @ k.T                                 # (bq, bk)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_cur

    @pl.when(j == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_hsd(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None, block_q=128, block_k=128,
                        interpret=False):
    """q: (BH, S, D), k/v: (BH, Skv, D) -- batch*head already folded.

    Returns (BH, S, D) in q.dtype. S, Skv must be block multiples (wrapper
    pads).
    """
    BH, S, D = q.shape
    Skv = k.shape[1]
    bq = min(block_q, S)
    bk = min(block_k, Skv)
    nq, nk = S // bq, Skv // bk
    scale = D ** -0.5 if scale is None else scale

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                          causal=causal, window=window, softcap=softcap),
        grid=(BH, nq, nk),
        in_specs=[pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
                  pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0))],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    scale=None, block_q=128, block_k=128, interpret=False):
    """q: (B, S, H, D); k/v: (B, Skv, Hkv, D) with H % Hkv == 0 (GQA).

    Pads S/Skv to block multiples, folds (B, H), repeats kv heads per group
    (gather view, not a copy after XLA fusion), unfolds back.
    """
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv

    pq = (-S) % min(block_q, max(S, 1))
    pk = (-Skv) % min(block_k, max(Skv, 1))
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S + pq, D)
    kg = jnp.repeat(k, group, axis=2)
    vg = jnp.repeat(v, group, axis=2)
    kf = kg.transpose(0, 2, 1, 3).reshape(B * H, Skv + pk, D)
    vf = vg.transpose(0, 2, 1, 3).reshape(B * H, Skv + pk, D)

    o = flash_attention_hsd(qf, kf, vf, causal=causal, window=window,
                            softcap=softcap, scale=scale, block_q=block_q,
                            block_k=block_k, interpret=interpret)
    o = o.reshape(B, H, S + pq, D).transpose(0, 2, 1, 3)
    return o[:, :S]
