"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lars_update_ref(p, g, v, *, lr, mom, eta, weight_decay, eps):
    """Fused LARS elementwise update, fp32.

    trust = eta*||p|| / (||g|| + wd*||p|| + eps)  (1.0 when either norm is 0)
    v'    = mom*v + trust*lr*(g + wd*p)
    p'    = p - v'
    """
    p = p.astype(jnp.float32)
    g = g.astype(jnp.float32)
    v = v.astype(jnp.float32)
    w_norm = jnp.linalg.norm(p)
    g_norm = jnp.linalg.norm(g)
    trust = jnp.where((w_norm > 0) & (g_norm > 0),
                      eta * w_norm / (g_norm + weight_decay * w_norm + eps),
                      1.0)
    v_new = mom * v + (trust * lr) * (g + weight_decay * p)
    return p - v_new, v_new


def ls_xent_ref(logits, labels, smoothing):
    """Per-row label-smoothed NLL (same math as core.losses.ls_xent_ref)."""
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return (1.0 - smoothing) * nll - smoothing * logp.mean(axis=-1)


def rmsnorm_ref(x, scale, eps=1e-6):
    """Gemma-style (1+w) RMSNorm, fp32 math."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def flash_attention_ref(q, k, v, *, causal=True, window=None, softcap=None,
                        scale=None):
    """Oracle for kernels.flash_attn: plain masked softmax attention.

    q: (B, S, H, D); k/v: (B, Skv, Hkv, D), GQA by head repetition.
    """
    B, S, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = D ** -0.5 if scale is None else scale
    k = jnp.repeat(k, group, axis=2).astype(jnp.float32)
    v = jnp.repeat(v, group, axis=2).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", qf * scale, k)
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(S)[:, None]
    kj = jnp.arange(Skv)[None, :]
    mask = jnp.ones((S, Skv), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v).astype(q.dtype)
