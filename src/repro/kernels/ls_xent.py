"""Fused label-smoothed cross-entropy kernel (Pallas, TPU target).

For 256K-vocab archs the loss is memory-bound: log_softmax materializes a
(B*S, V) fp32 tensor (134 MB per 128 rows at V=256k) and reads it twice.
This kernel streams the vocab dimension in VMEM tiles with an *online
logsumexp* (flash-attention-style rescaling), keeping only (rows,) running
accumulators; logits are read exactly once and no (rows, V) intermediate is
ever written.

Grid: (row_blocks, vocab_blocks); vocab is the inner (minor) loop so the
accumulators live across the j-sweep in VMEM scratch.

Per row r with label y, smoothing a, vocab K:
    loss = (1-a) * (lse - logit_y) - a * (sum_logits / K - lse)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ls_xent_kernel(labels_ref, logits_ref, out_ref,
                    m_ref, s_ref, sum_ref, lab_ref, *, nv_blocks, bv,
                    smoothing, vocab):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        s_ref[...] = jnp.zeros_like(s_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        lab_ref[...] = jnp.zeros_like(lab_ref)

    x = logits_ref[...].astype(jnp.float32)            # (br, bv)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, x.max(axis=1))
    scale = jnp.exp(m_prev - m_cur)
    s_ref[...] = s_ref[...] * scale + jnp.exp(x - m_cur[:, None]).sum(axis=1)
    m_ref[...] = m_cur
    # exclude vocab padding columns from the plain sum
    gcol = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1) + j * bv
    sum_ref[...] = sum_ref[...] + jnp.where(gcol < vocab, x, 0.0).sum(axis=1)

    # label logit if it falls in this vocab tile
    labels = labels_ref[...]                           # (br,)
    col = labels - j * bv
    in_tile = (col >= 0) & (col < bv)
    cols = jnp.clip(col, 0, bv - 1)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
              == cols[:, None]) & in_tile[:, None]
    lab_ref[...] = lab_ref[...] + jnp.where(onehot, x, 0.0).sum(axis=1)

    @pl.when(j == nv_blocks - 1)
    def _finish():
        lse = m_ref[...] + jnp.log(s_ref[...])
        nll = lse - lab_ref[...]
        mean_logit = sum_ref[...] / vocab
        out_ref[...] = (1.0 - smoothing) * nll - smoothing * (mean_logit - lse)


def ls_xent_pallas(logits, labels, *, smoothing: float,
                   block_rows: int = 128, block_vocab: int = 2048,
                   interpret: bool = False):
    """logits: (..., V) float; labels: (...) int32 -> per-row loss fp32."""
    batch_shape = logits.shape[:-1]
    V = logits.shape[-1]
    R = 1
    for d in batch_shape:
        R *= d
    x = logits.reshape(R, V)
    y = labels.reshape(R).astype(jnp.int32)

    br = min(block_rows, R)
    bv = min(block_vocab, V)
    # pad rows/vocab to block multiples (pad logits with -1e30: no effect
    # on lse; sum_logits correction only affects padded rows we discard)
    Rp, Vp = -(-R // br) * br, -(-V // bv) * bv
    if Rp != R or Vp != V:
        x = jnp.pad(x, ((0, Rp - R), (0, Vp - V)), constant_values=-1e30)
        y = jnp.pad(y, (0, Rp - R))
    grid = (Rp // br, Vp // bv)

    out = pl.pallas_call(
        functools.partial(_ls_xent_kernel, nv_blocks=grid[1], bv=bv,
                          smoothing=smoothing, vocab=V),
        grid=grid,
        in_specs=[pl.BlockSpec((br,), lambda i, j: (i,)),
                  pl.BlockSpec((br, bv), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((Rp,), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((br,), jnp.float32),   # running max
            pltpu.VMEM((br,), jnp.float32),   # running sumexp
            pltpu.VMEM((br,), jnp.float32),   # running sum of logits
            pltpu.VMEM((br,), jnp.float32),   # label logit
        ],
        interpret=interpret,
    )(y, x)
    return out[:R].reshape(batch_shape)
