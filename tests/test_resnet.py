"""ResNet-50 model: shapes, param count, SyncBN, zero-gamma init."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models import resnet


def test_resnet50_param_count():
    cfg = resnet.ResNetConfig.resnet50()
    params = resnet.init(jax.random.key(0), cfg)
    n = resnet.num_params(params)
    # ResNet-50 ~= 25.6M params
    assert 25.0e6 < n < 26.2e6, n


def test_tiny_forward_shapes_and_finite():
    cfg = resnet.ResNetConfig.tiny()
    params = resnet.init(jax.random.key(0), cfg)
    x = jnp.ones((2, 32, 32, 3), jnp.float32)
    logits = resnet.apply(params, x, cfg)
    assert logits.shape == (2, 10)
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()


def test_zero_gamma_makes_blocks_identity_at_init():
    """With bn3 gamma zero-init, each residual block is ~identity+relu at
    init -- output variance should stay bounded through depth."""
    cfg = resnet.ResNetConfig.tiny()
    params = resnet.init(jax.random.key(1), cfg)
    for stage in params["stages"]:
        for block in stage:
            np.testing.assert_array_equal(np.asarray(block["bn3"]["bn_scale"]), 0.0)


def test_collect_and_reuse_stats():
    cfg = resnet.ResNetConfig.tiny()
    params = resnet.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(2), (4, 32, 32, 3))
    logits_train, stats = resnet.apply(params, x, cfg, collect_stats=True)
    logits_eval = resnet.apply(params, x, cfg, stats=stats)
    # same batch + its own stats == train-mode output
    np.testing.assert_allclose(np.asarray(logits_train),
                               np.asarray(logits_eval), rtol=1e-3, atol=1e-3)


@pytest.mark.multidevice
def test_sync_bn_matches_global_batch():
    """SyncBN over the data axis == local BN over the concatenated batch."""
    mesh = jax.make_mesh((4,), ("data",))
    cfg = resnet.ResNetConfig.tiny(compute_dtype=jnp.float32)
    params = resnet.init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(3), (8, 32, 32, 3))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P(), P("data")), out_specs=P("data"),
                       check_vma=False)
    def sharded(params, xb):
        return resnet.apply(params, xb, cfg, dp_axes=("data",))

    got = np.asarray(jax.jit(sharded)(params, x))
    want = np.asarray(resnet.apply(params, x, cfg))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
