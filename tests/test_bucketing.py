"""Bucketed gradient sync: partition invariants, property-based round-trip
vs the psum oracle (all four strategies x both lowerings x bucketed/fused),
precision-group preservation, the reverse-backprop issue order, the HLO
audit proving independent per-bucket collectives, and the per-bucket
alpha-beta cost model."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _hyp import given, settings, strategies as st
from repro.compat import shard_map
from repro.core import collectives
from repro.core.grad_sync import (GradSyncConfig, bucket_layout,
                                  partition_buckets, sync_tree)
from repro.core.topology import TorusGrid
from repro.launch import hlo_stats

WORLD = 8
GRID = TorusGrid(h_axes=("dx",), v_axes=("dy",))

MESH = None


def get_mesh():
    global MESH
    if MESH is None:
        MESH = jax.make_mesh((2, 4), ("dy", "dx"))
    return MESH


# ------------------------------------------------------------ partition --

@settings(max_examples=30, deadline=None)
@given(sizes=st.lists(st.integers(0, 4096), min_size=0, max_size=40),
       bucket_bytes=st.integers(0, 8192))
def test_partition_buckets_invariants(sizes, bucket_bytes):
    buckets = partition_buckets(sizes, bucket_bytes)
    # exact, order-preserving partition of the index range
    flat = [i for b in buckets for i in b]
    assert flat == list(range(len(sizes)))
    assert all(b for b in buckets)
    if bucket_bytes <= 0:
        assert len(buckets) <= 1
    else:
        # every bucket except the last meets the size target
        for b in buckets[:-1]:
            assert sum(sizes[i] for i in b) >= bucket_bytes


def test_partition_single_oversized_leaf_gets_own_bucket():
    assert partition_buckets([100, 5, 5], 10) == [[0], [1, 2]]


@settings(max_examples=50, deadline=None)
@given(sizes=st.lists(st.integers(0, 4096), min_size=0, max_size=40),
       bucket_bytes=st.integers(1, 8192))
def test_partition_no_undersized_tail_bucket(sizes, bucket_bytes):
    """Regression (ISSUE 10): the greedy partition used to leave a trailing
    bucket far below target (worst case one tiny leaf) -- a pure-latency
    straggler issued last. Whenever there are >=2 buckets, every bucket,
    the last included, must now be >= bucket_bytes/2."""
    buckets = partition_buckets(sizes, bucket_bytes)
    if len(buckets) >= 2:
        for b in buckets:
            assert 2 * sum(sizes[i] for i in b) >= bucket_bytes


def test_partition_tail_merge_regression():
    # [10, 10, 1]: tail bucket [1] is < target/2 -> merged into predecessor
    assert partition_buckets([10, 10, 1], 10) == [[0], [1, 2]]
    # a tail >= half the target stays its own bucket
    assert partition_buckets([10, 10, 6], 10) == [[0], [1], [2]]
    # single bucket total: nothing to merge into
    assert partition_buckets([3], 10) == [[0]]


# --------------------------------------------------------------- layout --

def _mixed_tree(rng):
    return {
        "dense": {"kernel": rng.randn(WORLD, 40, 7).astype(np.float32),
                  "bias": rng.randn(WORLD, 7).astype(np.float32)},
        "bn": {"scale": rng.randn(WORLD, 5).astype(np.float32)},
        "emb": rng.randn(WORLD, 33).astype(np.float32),
    }


def test_layout_preserves_precision_groups():
    tree = {"a": {"kernel": np.zeros((64, 4), np.float32)},
            "bn": {"scale": np.zeros(8, np.float32)},
            "b": {"kernel": np.zeros((64, 4), np.float32)}}
    cfg = GradSyncConfig(bucket_bytes=128, comm_dtype=jnp.bfloat16)
    layout = bucket_layout(tree, cfg)
    for b in layout:
        assert b["dtype"] == ("float32" if b["group"] == "fp32" else "bfloat16")
        if b["group"] == "fp32":
            assert all("bn" in p or "bias" in p or "scale" in p
                       for p in b["paths"])


def test_layout_reverse_backprop_order():
    """The first issued bucket must hold the LAST leaves in flatten order
    (whose grads backprop produces first)."""
    tree = {f"layer{i:02d}": {"kernel": np.zeros((256, 4), np.float32)}
            for i in range(8)}
    cfg = GradSyncConfig(bucket_bytes=2 * 256 * 4 * 4,
                         comm_dtype=jnp.float32)
    layout = [b for b in bucket_layout(tree, cfg) if b["group"] == "comm"]
    assert len(layout) == 4
    assert layout[0]["paths"] == ["layer07/kernel", "layer06/kernel"]
    assert layout[-1]["paths"] == ["layer01/kernel", "layer00/kernel"]

    fwd = GradSyncConfig(bucket_bytes=cfg.bucket_bytes,
                         comm_dtype=jnp.float32, reverse_order=False)
    layout_fwd = [b for b in bucket_layout(tree, fwd) if b["group"] == "comm"]
    assert layout_fwd[0]["paths"] == ["layer00/kernel", "layer01/kernel"]


def test_layout_zero_bucket_bytes_is_single_fused_buffer():
    rng = np.random.RandomState(0)
    layout = bucket_layout(_mixed_tree(rng), GradSyncConfig(bucket_bytes=0))
    assert [b["group"] for b in layout] == ["comm", "fp32"]


# ------------------------------------------------------- sync round-trip --

def run_sync(tree_per_rank, cfg):
    mesh = get_mesh()
    spec = P(("dy", "dx"))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=spec, out_specs=spec, check_vma=False)
    def f(tree):
        local = jax.tree.map(lambda x: x[0], tree)
        out = sync_tree(local, GRID, cfg)
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(f)(tree_per_rank)


def oracle(tree_per_rank):
    return jax.tree.map(
        lambda x: np.asarray(x, np.float32).sum(0) / WORLD, tree_per_rank)


@pytest.mark.multidevice
@pytest.mark.parametrize("strategy", ["psum", "ring", "hierarchical", "torus2d"])
@pytest.mark.parametrize("lowering", ["xla", "ring"])
def test_bucketed_sync_matches_oracle_all_strategies(strategy, lowering):
    rng = np.random.RandomState(0)
    tree = _mixed_tree(rng)
    cfg = GradSyncConfig(strategy=strategy, lowering=lowering, fuse=True,
                         comm_dtype=jnp.float32, bucket_bytes=512)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.broadcast_to(b, np.asarray(a).shape),
            rtol=1e-5, atol=1e-5),
        out, oracle(tree))


@pytest.mark.multidevice
@settings(max_examples=15, deadline=None)
@given(
    shapes=st.lists(
        st.lists(st.integers(1, 9), min_size=0, max_size=3),
        min_size=1, max_size=6),
    strategy=st.sampled_from(["psum", "ring", "hierarchical", "torus2d"]),
    lowering=st.sampled_from(["xla", "ring"]),
    bucket_bytes=st.sampled_from([0, 64, 300, 1 << 20]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_bucketed_roundtrip(shapes, strategy, lowering, bucket_bytes,
                                     seed):
    """Any pytree -> bucket partition -> sync -> unpartition reproduces the
    psum mean on every rank, for every strategy/lowering/bucket size."""
    rng = np.random.RandomState(seed)
    tree = {f"w{i}": rng.randn(WORLD, *s).astype(np.float32)
            for i, s in enumerate(shapes)}
    cfg = GradSyncConfig(strategy=strategy, lowering=lowering, fuse=True,
                         comm_dtype=jnp.float32, bucket_bytes=bucket_bytes)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.broadcast_to(b, np.asarray(a).shape),
            rtol=1e-4, atol=1e-5),
        out, oracle(tree))


@pytest.mark.multidevice
def test_bucketed_bitexact_vs_fused_for_psum():
    """Bucketing only changes buffer packing, not per-element reduction
    order, for the xla psum lowering: results must be bit-exact equal to the
    single-fused-buffer baseline."""
    rng = np.random.RandomState(7)
    tree = _mixed_tree(rng)
    outs = []
    for bb in (0, 400):
        cfg = GradSyncConfig(strategy="psum", fuse=True,
                             comm_dtype=jnp.float32, bucket_bytes=bb)
        outs.append(run_sync(jax.tree.map(jnp.asarray, tree), cfg))
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        outs[0], outs[1])


# ------------------------------------------------------------ HLO audit --

@pytest.mark.multidevice
def test_hlo_shows_independent_collectives_per_bucket():
    """The structural overlap proof: a multi-bucket config compiles to >=2
    independent reduction exchanges where the fused config shows exactly 1."""
    mesh = get_mesh()
    # comm-group-only tree (no bn/bias/scale) so fused == exactly 1 exchange
    tree = {f"w{i}": jnp.zeros((64, 64), jnp.float32) for i in range(8)}

    def compile_audit(bucket_bytes):
        cfg = GradSyncConfig(strategy="torus2d", fuse=True,
                             comm_dtype=jnp.float32,
                             bucket_bytes=bucket_bytes)

        @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                           check_vma=False)
        def f(t):
            return sync_tree(t, GRID, cfg)

        hlo = jax.jit(f).lower(tree).compile().as_text()
        return hlo_stats.bucket_audit(hlo, min_bytes=1024)

    fused = compile_audit(0)
    assert fused["num_exchanges"] == 1, fused["by_kind"]

    bucketed = compile_audit(32 * 1024)
    assert bucketed["num_exchanges"] >= 2, bucketed["by_kind"]
    # every bucket produced its own full torus chain
    assert bucketed["by_kind"]["reduce-scatter"]["count"] == 4
    assert bucketed["by_kind"]["all-gather"]["count"] == 4


# ------------------------------------------------- per-leaf path grouping --

def _many_small_leaves_tree(n=24):
    """A TP-ish model slice: a few large kernels plus many small replicated
    scales/biases -- the regime where one-psum-per-leaf is latency-bound."""
    rng = np.random.RandomState(3)
    tree = {}
    for i in range(3):
        tree[f"block{i}"] = {
            "kernel": rng.randn(WORLD, 512, 8).astype(np.float32)}
    for i in range(n):
        tree[f"norm{i:02d}"] = {
            "gain": rng.randn(WORLD, 17).astype(np.float32)}
    return tree


def test_per_leaf_layout_groups_small_leaves():
    tree = jax.tree.map(lambda x: x[0], _many_small_leaves_tree())
    cfg = GradSyncConfig(fuse=False, comm_dtype=jnp.float32, bucket_bytes=0)
    layout = bucket_layout(tree, cfg)
    per_leaf = [b for b in layout if b["mode"] == "per_leaf"]
    grouped = [b for b in layout if b["mode"] == "grouped"]
    assert len(per_leaf) == 3            # the large kernels
    assert len(grouped) == 1             # all 24 gains share one psum
    assert grouped[0]["num_leaves"] == 24
    # bucket_bytes partitions the shared buffer too
    cfg_b = GradSyncConfig(fuse=False, comm_dtype=jnp.float32,
                           bucket_bytes=6 * 17 * 4)
    grouped_b = [b for b in bucket_layout(tree, cfg_b)
                 if b["mode"] == "grouped"]
    assert len(grouped_b) == 4


@pytest.mark.multidevice
def test_per_leaf_grouped_sync_matches_oracle():
    tree = _many_small_leaves_tree()
    for bb in (0, 6 * 17 * 4):
        cfg = GradSyncConfig(strategy="torus2d", fuse=False,
                             comm_dtype=jnp.float32, bucket_bytes=bb)
        out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.broadcast_to(b, np.asarray(a).shape),
                rtol=1e-5, atol=1e-5),
            out, oracle(tree))


@pytest.mark.multidevice
def test_per_leaf_grouping_reduces_hlo_collectives():
    """Acceptance criterion (ISSUE 10): for a model with many small leaves
    the fuse=False path must compile to fewer collective ops than
    one-exchange-per-leaf."""
    mesh = get_mesh()
    n_small = 24
    tree = jax.tree.map(lambda x: x[0],
                        _many_small_leaves_tree(n_small))
    n_leaves = len(jax.tree.leaves(tree))
    cfg = GradSyncConfig(strategy="torus2d", fuse=False,
                         comm_dtype=jnp.float32, bucket_bytes=0)

    @functools.partial(shard_map, mesh=mesh, in_specs=P(), out_specs=P(),
                       check_vma=False)
    def f(t):
        return sync_tree(t, GRID, cfg)

    hlo = jax.jit(f).lower(tree).compile().as_text()
    n_coll = len(hlo_stats.collective_schedule(hlo))
    # old behavior: >= one collective per leaf (torus2d large leaves emit
    # several). New: 3 large-leaf chains + ONE grouped psum.
    assert n_coll < n_leaves, (n_coll, n_leaves)
    # 3 large-leaf torus chains (one y-phase all-reduce each) + exactly ONE
    # grouped psum covering all 24 small leaves
    n_ar = sum(1 for op in hlo_stats.collective_schedule(hlo)
               if op["kind"] == "all-reduce")
    assert n_ar == 4, n_ar


# ------------------------------------------------------------ cost model --

def test_bucketed_cost_model_latency_vs_overlap():
    nbytes, x, y = 51e6, 16, 16
    bw, lat = 50e9, 1e-6
    fused = collectives.bucketed_comm_cost_model(
        "torus2d", nbytes, 0, x, y, bw, lat, backward_seconds=0.040)
    bucketed = collectives.bucketed_comm_cost_model(
        "torus2d", nbytes, 4 << 20, x, y, bw, lat, backward_seconds=0.040)
    assert fused["num_buckets"] == 1
    assert bucketed["num_buckets"] == 13
    # more buckets -> more total latency on the wire...
    assert bucketed["serial_seconds"] > fused["serial_seconds"]
    # ...but overlap with backprop hides most of it
    assert bucketed["exposed_seconds"] < fused["fused_exposed_seconds"]
    assert bucketed["overlap_win_seconds"] > 0

    # without a backward pass to hide behind, bucketing is strictly worse
    no_overlap = collectives.bucketed_comm_cost_model(
        "torus2d", nbytes, 4 << 20, x, y, bw, lat, backward_seconds=0.0)
    assert no_overlap["exposed_seconds"] >= fused["fused_exposed_seconds"]


def test_bucketed_cost_model_bucket_sizes_sum():
    m = collectives.bucketed_comm_cost_model(
        "ring", 10_000_000, 3_000_000, 8, 8, 50e9, 1e-6)
    assert m["num_buckets"] == 4
    assert sum(c["wire_bytes"] for c in m["per_bucket"]) > 0
