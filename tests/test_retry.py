"""The shared jittered-exponential-backoff helper (repro.utils.retry):
one retry loop for checkpoint saves, batch fetches, and elastic recovery."""

import pytest

from repro.utils.retry import retry_call


class Flaky:
    """Fails the first ``n_failures`` calls with ``exc_type``."""

    def __init__(self, n_failures, exc_type=OSError):
        self.n_failures = n_failures
        self.exc_type = exc_type
        self.calls = 0

    def __call__(self):
        self.calls += 1
        if self.calls <= self.n_failures:
            raise self.exc_type(f"fail #{self.calls}")
        return "ok"


def test_succeeds_after_transient_failures():
    fn = Flaky(2)
    slept = []
    assert retry_call(fn, retries=3, backoff_s=0.01,
                      sleep=slept.append) == "ok"
    assert fn.calls == 3
    assert len(slept) == 2


def test_exhaustion_reraises_last_exception():
    fn = Flaky(99)
    with pytest.raises(OSError, match="fail #4"):
        retry_call(fn, retries=3, backoff_s=0.01, sleep=lambda d: None)
    assert fn.calls == 4                     # attempt 0 + 3 retries


def test_non_retryable_propagates_immediately():
    fn = Flaky(99, exc_type=ValueError)
    with pytest.raises(ValueError, match="fail #1"):
        retry_call(fn, retries=3, retry_on=(OSError,), sleep=lambda d: None)
    assert fn.calls == 1


def test_backoff_is_exponential_with_bounded_jitter():
    slept = []
    with pytest.raises(OSError):
        retry_call(Flaky(99), retries=4, backoff_s=0.1, jitter=0.25,
                   max_backoff_s=100.0, sleep=slept.append)
    assert len(slept) == 4
    for k, d in enumerate(slept):
        base = 0.1 * 2 ** k
        assert base <= d <= base * 1.25      # jitter adds at most 25%


def test_max_backoff_caps_delay():
    slept = []
    with pytest.raises(OSError):
        retry_call(Flaky(99), retries=5, backoff_s=1.0, jitter=0.0,
                   max_backoff_s=2.0, sleep=slept.append)
    assert slept == [1.0, 2.0, 2.0, 2.0, 2.0]


def test_jitter_is_deterministic_in_seed():
    def delays(seed):
        slept = []
        with pytest.raises(OSError):
            retry_call(Flaky(99), retries=3, backoff_s=0.1, seed=seed,
                       sleep=slept.append)
        return slept

    assert delays(7) == delays(7)
    assert delays(7) != delays(8)


def test_deadline_cap_stops_retrying_early():
    """A sleep that would cross the deadline is never taken: the last
    exception surfaces instead of burning wall-clock on doomed retries."""
    now = [0.0]
    slept = []

    def sleep(d):
        slept.append(d)
        now[0] += d

    fn = Flaky(99)
    with pytest.raises(OSError):
        retry_call(fn, retries=10, backoff_s=1.0, jitter=0.0,
                   max_backoff_s=100.0, deadline_s=5.0,
                   sleep=sleep, clock=lambda: now[0])
    # delays 1, 2 fit (elapsed 3); the next delay 4 would cross 5.0s
    assert slept == [1.0, 2.0]
    assert fn.calls == 3


def test_on_retry_observes_each_retried_attempt():
    seen = []
    fn = Flaky(2)
    retry_call(fn, retries=3, backoff_s=0.01, sleep=lambda d: None,
               on_retry=lambda a, e: seen.append((a, str(e))))
    assert [a for a, _ in seen] == [0, 1]
    assert all("fail" in msg for _, msg in seen)


def test_zero_retries_single_attempt():
    fn = Flaky(1)
    with pytest.raises(OSError):
        retry_call(fn, retries=0, sleep=lambda d: None)
    assert fn.calls == 1
