"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.kernels import ops, ref


# ------------------------------------------------------------------ LARS --

SHAPES = [(7,), (128,), (64, 64), (33, 5), (8, 9, 10), (1, 1), (300, 129)]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lars_kernel_matches_ref(shape, dtype):
    rng = np.random.RandomState(hash(shape) % 2**31)
    p = jnp.asarray(rng.randn(*shape), dtype)
    g = jnp.asarray(rng.randn(*shape), dtype) * 0.1
    v = jnp.asarray(rng.randn(*shape), jnp.float32) * 0.01
    kw = dict(lr=0.5, mom=0.9, eta=0.01, weight_decay=5e-5, eps=1e-6)
    p_new, v_new = ops.lars_update(p, g, v, **kw, interpret=True)
    p_ref, v_ref = ref.lars_update_ref(p, g, v, **kw)
    np.testing.assert_allclose(np.asarray(p_new), np.asarray(p_ref),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v_new), np.asarray(v_ref),
                               rtol=1e-5, atol=1e-6)


def test_lars_kernel_zero_grad_trust_is_one():
    p = jnp.ones((16,))
    g = jnp.zeros((16,))
    v = jnp.zeros((16,))
    p_new, v_new = ops.lars_update(p, g, v, lr=1.0, mom=0.9, eta=0.01,
                                   weight_decay=0.0, eps=1e-6, interpret=True)
    np.testing.assert_allclose(np.asarray(p_new), 1.0)


def test_lars_kernel_jits_and_grads_flow():
    p = jnp.asarray(np.random.randn(50, 3), jnp.float32)
    g = jnp.ones_like(p)
    v = jnp.zeros_like(p)

    @jax.jit
    def f(p, g, v, lr):
        return ops.lars_update(p, g, v, lr=lr, mom=0.9, eta=0.01,
                               weight_decay=5e-5, eps=1e-6, interpret=True)
    p1, v1 = f(p, g, v, 0.1)
    assert p1.shape == p.shape and np.isfinite(np.asarray(p1)).all()


# --------------------------------------------------------------- LS-xent --

@pytest.mark.parametrize("rows,vocab", [(4, 16), (3, 300), (130, 2048),
                                        (5, 2049), (2, 5000), (1, 7)])
@pytest.mark.parametrize("smoothing", [0.0, 0.1])
def test_ls_xent_kernel_matches_ref(rows, vocab, smoothing):
    rng = np.random.RandomState(rows * 1000 + vocab)
    logits = jnp.asarray(rng.randn(rows, vocab) * 4, jnp.float32)
    labels = jnp.asarray(rng.randint(0, vocab, (rows,)), jnp.int32)
    got = ops.ls_xent(logits, labels, smoothing=smoothing, interpret=True)
    want = ref.ls_xent_ref(logits, labels, smoothing)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ls_xent_kernel_bf16_logits():
    rng = np.random.RandomState(0)
    logits = jnp.asarray(rng.randn(8, 512) * 3, jnp.bfloat16)
    labels = jnp.asarray(rng.randint(0, 512, (8,)), jnp.int32)
    got = ops.ls_xent(logits, labels, smoothing=0.1, interpret=True)
    want = ref.ls_xent_ref(logits.astype(jnp.float32), labels, 0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


def test_ls_xent_kernel_batched_shape():
    rng = np.random.RandomState(1)
    logits = jnp.asarray(rng.randn(2, 6, 100), jnp.float32)
    labels = jnp.asarray(rng.randint(0, 100, (2, 6)), jnp.int32)
    got = ops.ls_xent(logits, labels, smoothing=0.1, interpret=True)
    assert got.shape == (2, 6)
    want = ref.ls_xent_ref(logits, labels, 0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 20), vocab=st.integers(2, 600),
       scale=st.floats(0.1, 20.0), seed=st.integers(0, 999))
def test_ls_xent_property_sweep(rows, vocab, scale, seed):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(rows, vocab) * scale, jnp.float32)
    labels = jnp.asarray(rng.randint(0, vocab, (rows,)), jnp.int32)
    got = ops.ls_xent(logits, labels, smoothing=0.1, interpret=True)
    want = ref.ls_xent_ref(logits, labels, 0.1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_lars_optimizer_kernel_path_matches_ref_path():
    """core.lars with use_kernel=True == use_kernel=False."""
    from repro.core import lars
    rng = np.random.RandomState(2)
    params = {"w": {"kernel": jnp.asarray(rng.randn(32, 8), jnp.float32)}}
    grads = {"w": {"kernel": jnp.asarray(rng.randn(32, 8), jnp.float32)}}
    opt = lars.init(params)
    ref_p, ref_o = lars.update(params, grads, opt, lr=0.3, momentum=0.9,
                               cfg=lars.LARSConfig(use_kernel=False))
    ker_p, ker_o = lars.update(params, grads, opt, lr=0.3, momentum=0.9,
                               cfg=lars.LARSConfig(use_kernel=True))
    np.testing.assert_allclose(np.asarray(ker_p["w"]["kernel"]),
                               np.asarray(ref_p["w"]["kernel"]),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------- flash attention

@pytest.mark.parametrize("s,skv,h,hkv,d", [
    (64, 64, 2, 2, 32), (128, 128, 4, 2, 32), (96, 96, 2, 1, 64),
    (64, 128, 2, 2, 32),
])
def test_flash_attention_matches_ref(s, skv, h, hkv, d):
    rng = np.random.RandomState(s + skv)
    q = jnp.asarray(rng.randn(2, s, h, d), jnp.float32)
    k = jnp.asarray(rng.randn(2, skv, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(2, skv, hkv, d), jnp.float32)
    got = ops.flash_attention(q, k, v, interpret=True)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [16, 48])
def test_flash_attention_sliding_window(window):
    rng = np.random.RandomState(window)
    q = jnp.asarray(rng.randn(1, 128, 2, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 128, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 128, 2, 32), jnp.float32)
    got = ops.flash_attention(q, k, v, window=window, interpret=True)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_softcap_and_bf16():
    rng = np.random.RandomState(7)
    q = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.bfloat16)
    got = ops.flash_attention(q, k, v, softcap=50.0, interpret=True)
    want = ref.flash_attention_ref(q, k, v, softcap=50.0)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_equals_model_sdpa():
    """Kernel agrees with the model's attention path (same masking)."""
    from repro.nn import attention as A
    cfg = A.AttnConfig(d_model=64, n_heads=2, n_kv_heads=2, head_dim=32)
    rng = np.random.RandomState(9)
    q = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
    k = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
    v = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
    mask = A.causal_mask(64, 64)[None]
    want = A._sdpa(q, k, v, mask, cfg).reshape(1, 64, 2, 32)
    got = ops.flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
