"""Sequence-mixer correctness: SSD chunked form vs naive recurrence oracle,
RG-LRU scan vs step-by-step, MoE dispatch invariants, attention windowing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.nn import attention as A
from repro.nn import moe as M
from repro.nn import rglru as R
from repro.nn import ssm as S


# ------------------------------------------------------------------- SSD --

def naive_ssd(x, dt, A_, B_, C):
    """Token-by-token recurrence oracle: h = exp(dt A) h + dt B x."""
    Bb, Sl, H, P = x.shape
    N = B_.shape[-1]
    h = np.zeros((Bb, H, P, N))
    ys = []
    for t in range(Sl):
        decay = np.exp(dt[:, t] * A_)                 # (B,H)
        xb = np.einsum("bn,bh,bhp->bhpn", B_[:, t], dt[:, t], x[:, t])
        h = h * decay[..., None, None] + xb
        ys.append(np.einsum("bn,bhpn->bhp", C[:, t], h))
    return np.stack(ys, 1), h


@pytest.mark.parametrize("seqlen,chunk", [(8, 4), (16, 8), (12, 12)])
def test_ssd_chunked_matches_naive(seqlen, chunk):
    rng = np.random.RandomState(0)
    Bb, H, P, N = 2, 3, 4, 5
    x = rng.randn(Bb, seqlen, H, P).astype(np.float32)
    dt = rng.uniform(0.01, 0.2, (Bb, seqlen, H)).astype(np.float32)
    A_ = -rng.uniform(0.5, 2.0, (H,)).astype(np.float32)
    B_ = rng.randn(Bb, seqlen, N).astype(np.float32)
    C = rng.randn(Bb, seqlen, N).astype(np.float32)

    cfg = S.SSDConfig(d_model=1, chunk=chunk)
    y, h = S._ssd_chunked(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A_),
                          jnp.asarray(B_), jnp.asarray(C), cfg)
    y_ref, h_ref = naive_ssd(x, dt, A_, B_, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_ssd_block_prefill_then_decode_matches_full():
    cfg = S.SSDConfig(d_model=32, d_state=8, head_dim=8, chunk=4)
    p = S.ssd_init(jax.random.key(0), cfg)
    u = jax.random.normal(jax.random.key(1), (1, 9, 32))
    full = S.ssd_apply(p, u, cfg)
    out8, state = S.ssd_apply(p, u[:, :8], cfg, return_state=True)
    out_last, _ = S.ssd_decode_step(p, u[:, 8:9], state, cfg)
    np.testing.assert_allclose(np.asarray(full[:, :8]), np.asarray(out8),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(full[:, 8:9]), np.asarray(out_last),
                               rtol=1e-3, atol=1e-3)


# ----------------------------------------------------------------- RG-LRU --

def test_rglru_scan_matches_stepwise():
    cfg = R.RGLRUConfig(d_model=16)
    p = R.rglru_init(jax.random.key(0), cfg)
    u = jax.random.normal(jax.random.key(1), (2, 7, 16))
    full, state_full = R.rglru_apply(p, u, cfg, return_state=True)
    state = R.rglru_init_state(2, cfg, jnp.float32)
    outs = []
    for t in range(7):
        y, state = R.rglru_decode_step(p, u[:, t: t + 1], state, cfg)
        outs.append(y)
    step = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(state_full["hidden"]),
                               np.asarray(state["hidden"]), rtol=1e-4, atol=1e-4)


def test_rglru_state_carry_across_segments():
    cfg = R.RGLRUConfig(d_model=8)
    p = R.rglru_init(jax.random.key(2), cfg)
    u = jax.random.normal(jax.random.key(3), (1, 10, 8))
    full = R.rglru_apply(p, u, cfg)
    _, st = R.rglru_apply(p, u[:, :6], cfg, return_state=True)
    seg2 = R.rglru_apply(p, u[:, 6:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(full[:, 6:]), np.asarray(seg2),
                               rtol=1e-4, atol=1e-4)


def test_rglru_decay_bounded():
    """a_t in (0,1): hidden state cannot blow up."""
    cfg = R.RGLRUConfig(d_model=8)
    p = R.rglru_init(jax.random.key(4), cfg)
    u = 100.0 * jax.random.normal(jax.random.key(5), (1, 50, 8))
    y, st = R.rglru_apply(p, u, cfg, return_state=True)
    assert np.isfinite(np.asarray(y)).all()
    assert np.isfinite(np.asarray(st["hidden"])).all()


# -------------------------------------------------------------------- MoE --

def test_moe_no_drop_equals_dense_mixture():
    """With capacity >= T every token gets exactly its top-k mixture."""
    cfg = M.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=2,
                      capacity_factor=2.0)   # cap = T*k/E * 2 = T -> no drops
    p = M.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 6, 8))
    y, aux = M.moe_apply(p, x, cfg)

    # dense oracle: run every expert on every token, combine with gates
    xt = x.reshape(-1, 8)
    logits = xt @ p["router"]["kernel"]
    probs = jax.nn.softmax(logits, -1)
    gv, ge = jax.lax.top_k(probs, 2)
    gv = gv / gv.sum(-1, keepdims=True)
    w = p["experts"]
    h = jnp.einsum("td,edf->etf", xt, w["up"])
    g = jnp.einsum("td,edf->etf", xt, w["gate"])
    ye = jnp.einsum("etf,efd->etd", h * jax.nn.silu(g), w["down"])
    want = jnp.zeros_like(xt)
    for slot in range(2):
        want = want + gv[:, slot, None] * ye[ge[:, slot], jnp.arange(xt.shape[0])]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 8)), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens_not_crash():
    cfg = M.MoEConfig(d_model=8, d_ff=16, n_experts=4, top_k=2,
                      capacity_factor=0.25)
    p = M.moe_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 8, 8))
    y, aux = M.moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


@settings(max_examples=10, deadline=None)
@given(t=st.integers(2, 16), e=st.integers(2, 8), seed=st.integers(0, 99))
def test_moe_property_output_finite_and_bounded(t, e, seed):
    k = min(2, e)
    cfg = M.MoEConfig(d_model=4, d_ff=8, n_experts=e, top_k=k)
    p = M.moe_init(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (1, t, 4))
    y, aux = M.moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert 0 <= float(aux) < 10 * e


# -------------------------------------------------------------- attention --

def test_sliding_window_masks_out_far_tokens():
    """Token attending beyond its window must have zero weight: compare a
    windowed forward with a manually-truncated input."""
    cfg = A.AttnConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8,
                       window=4)
    p = A.attn_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 10, 16))
    out = A.self_attention(p, x, cfg)
    # last position attends to positions 6..9 only; perturbing position 0
    # must not change the last output
    x2 = x.at[:, 0].set(x[:, 0] + 100.0)
    out2 = A.self_attention(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(out[:, -1]), np.asarray(out2[:, -1]),
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(out[:, 1]), np.asarray(out2[:, 1]))


def test_gqa_grouping_matches_repeated_kv():
    """GQA with kv groups == full MHA when kv heads are tiled."""
    cfg_gqa = A.AttnConfig(d_model=16, n_heads=4, n_kv_heads=2, head_dim=4)
    p = A.attn_init(jax.random.key(0), cfg_gqa)
    x = jax.random.normal(jax.random.key(1), (1, 6, 16))
    out = A.self_attention(p, x, cfg_gqa)

    cfg_mha = A.AttnConfig(d_model=16, n_heads=4, n_kv_heads=4, head_dim=4)
    p_mha = dict(p)
    # tile kv kernels head-wise: (d, 2*4) -> (d, 4*4) repeating each group
    for name in ("k", "v"):
        kern = p[name]["kernel"].reshape(16, 2, 4)
        p_mha[name] = {"kernel": jnp.repeat(kern, 2, axis=1).reshape(16, 16)}
    out_mha = A.self_attention(p_mha, x, cfg_mha)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_mha),
                               rtol=1e-4, atol=1e-4)


def test_rolling_cache_long_decode():
    """Decode far past the cache length: rolling buffer must agree with
    full-sequence attention restricted to the window."""
    cfg = A.AttnConfig(d_model=8, n_heads=2, n_kv_heads=2, head_dim=4,
                       window=4)
    p = A.attn_init(jax.random.key(0), cfg)
    S_total = 12
    xs = jax.random.normal(jax.random.key(1), (1, S_total, 8))
    full = A.self_attention(p, xs, cfg)

    cache = A.init_kv_cache(1, 4, cfg, jnp.float32)
    outs = []
    for t in range(S_total):
        o, cache = A.decode_self_attention(p, xs[:, t: t + 1], cache, t, cfg)
        outs.append(o)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec),
                               rtol=1e-4, atol=1e-4)


def test_unrolled_paths_match_scanned():
    """The cost-analysis unrolled variants are numerically identical."""
    # q-chunked attention: unroll vs scan
    cfg = A.AttnConfig(d_model=16, n_heads=2, n_kv_heads=2, head_dim=8)
    p = A.attn_init(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 64, 16))
    a = A.self_attention(p, x, cfg, q_chunk=16, unroll=False)
    b = A.self_attention(p, x, cfg, q_chunk=16, unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)
    # SSD inter-chunk recurrence: unroll vs scan
    c1 = S.SSDConfig(d_model=32, d_state=8, head_dim=8, chunk=4)
    c2 = S.SSDConfig(d_model=32, d_state=8, head_dim=8, chunk=4,
                     unroll_scan=True)
    ps = S.ssd_init(jax.random.key(2), c1)
    u = jax.random.normal(jax.random.key(3), (1, 16, 32))
    np.testing.assert_allclose(np.asarray(S.ssd_apply(ps, u, c1)),
                               np.asarray(S.ssd_apply(ps, u, c2)),
                               rtol=1e-5, atol=1e-5)
