"""Per-architecture smoke tests: reduced config, one forward + train step +
prefill/decode step on CPU; output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.shapes import long_context_variant
from repro.core import losses
from repro.models import transformer as T

ARCHS = registry.ARCH_IDS


def _inputs(cfg, batch=2, seq=32):
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab, (batch, seq)), jnp.int32)
    vision = None
    if cfg.vision_tokens:
        vision = jnp.asarray(
            rng.randn(batch, cfg.vision_tokens, cfg.cross_kv_dim), jnp.bfloat16)
    return tokens, vision


@pytest.mark.parametrize("arch_id", ARCHS)
def test_forward_shapes_and_finite(arch_id):
    cfg = registry.get_smoke(arch_id)
    params = T.init(jax.random.key(0), cfg)
    tokens, vision = _inputs(cfg)
    logits, aux = jax.jit(
        lambda p, t, v: T.forward(p, t, cfg, vision=v))(params, tokens, vision)
    assert logits.shape == (*tokens.shape, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch_id
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_train_step_reduces_loss(arch_id):
    """One SGD step on one batch must reduce that batch's loss."""
    cfg = registry.get_smoke(arch_id)
    params = T.init(jax.random.key(1), cfg)
    tokens, vision = _inputs(cfg, batch=2, seq=16)
    labels = jnp.roll(tokens, -1, axis=1)

    def loss_fn(p):
        logits, aux = T.forward(p, tokens, cfg, vision=vision)
        return losses.label_smoothing_xent(logits, labels, 0.1) + 0.01 * aux

    loss0, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss0))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), "NaN/inf gradients"
    assert float(gnorm) > 0, "no gradient signal"
    params2 = jax.tree.map(
        lambda p, g: p - 0.05 * g.astype(p.dtype) if p.dtype != jnp.int32 else p,
        params, grads)
    loss1 = jax.jit(lambda p: loss_fn(p))(params2)
    assert float(loss1) < float(loss0), (arch_id, float(loss0), float(loss1))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_prefill_then_decode_matches_forward(arch_id):
    """Decode step at position S must equal the forward logits when the
    model is run on the extended sequence (numerical agreement check)."""
    cfg = registry.get_smoke(arch_id)
    # fp32 + no-drop MoE capacity so prefill+decode == forward exactly
    cfg = T.ArchConfig(**{**cfg.__dict__, "compute_dtype": jnp.float32,
                          "moe_capacity_factor": (cfg.n_experts / cfg.top_k
                                                  if cfg.mlp == "moe" else 1.25)})
    params = T.init(jax.random.key(2), cfg)
    seq = 12
    tokens, vision = _inputs(cfg, batch=1, seq=seq + 1)
    prompt, nxt = tokens[:, :seq], tokens[:, seq:]

    logits_pre, cache = jax.jit(
        lambda p, t, v: T.prefill(p, t, cfg, vision=v, cache_len=seq + 8,
                                  cache_dtype=jnp.float32))(params, prompt, vision)
    logits_dec, _ = jax.jit(
        lambda p, t, c: T.decode_step(p, t, c, seq, cfg))(params, nxt, cache)

    full_logits, _ = jax.jit(
        lambda p, t, v: T.forward(p, t, cfg, vision=v))(params, tokens, vision)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, 0]), np.asarray(full_logits[:, seq - 1]),
        rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0]), np.asarray(full_logits[:, seq]),
        rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch_id", ["gemma2-27b", "llama3-405b", "qwen3-1.7b"])
def test_long_context_variant_is_windowed(arch_id):
    cfg = long_context_variant(registry.get_smoke(arch_id))
    assert all(k != "attn" for k in cfg.pattern)
    assert cfg.window is not None


def test_param_count_analytics_match_actual():
    for arch_id in ARCHS:
        cfg = registry.get_smoke(arch_id)
        params = T.init(jax.random.key(0), cfg)
        actual = sum(p.size for p in jax.tree.leaves(params))
        # analytic count excludes norms/biases/small tensors: within 10%
        est = cfg.num_params()
        assert abs(actual - est) / actual < 0.15, (arch_id, actual, est)


def test_full_config_param_counts():
    """Sanity-check the full (unallocated) configs against known sizes."""
    assert abs(registry.get("llama3-405b").num_params() - 405e9) / 405e9 < 0.03
    assert abs(registry.get("kimi-k2-1t-a32b").num_params() - 1.0e12) / 1e12 < 0.1
    active = registry.get("kimi-k2-1t-a32b").active_params()
    assert abs(active - 32e9) / 32e9 < 0.3
    assert abs(registry.get("gemma2-27b").num_params() - 27e9) / 27e9 < 0.15
    assert abs(registry.get("mamba2-2.7b").num_params() - 2.7e9) / 2.7e9 < 0.25
