"""End-to-end integration: the paper's full recipe (LARS + label smoothing +
batch-size control + 2D-torus grad sync + SyncBN + mixed precision) training
a tiny ResNet on synthetic data across an 8-device mesh."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticImageNet, SyntheticTokens
from repro.models import resnet
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.state import TrainState
from repro.train.trainer import Trainer, TrainerConfig, make_train_step


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("dy", "dx"))


def resnet_loss(cfg, smoothing):
    def loss_fn(params, batch, dp_axes):
        images, labels = batch
        logits = resnet.apply(params, images, cfg, dp_axes=dp_axes)
        return losses.label_smoothing_xent(
            logits, labels, smoothing), jnp.zeros((), jnp.float32)
    return loss_fn


@pytest.mark.slow
@pytest.mark.multidevice
def test_resnet_paper_recipe_converges(mesh):
    cfg = resnet.ResNetConfig.tiny(num_classes=8)
    data = SyntheticImageNet(num_classes=8, image_size=32, noise=0.3)
    # fractional-epoch stages: ~20 steps at 2/worker then ~10 at 4/worker,
    # staying inside schedule B's warmup range at this toy scale
    sched = BatchSchedule((BatchStage(0, 0.08, 2), BatchStage(0.08, 0.16, 4)))
    plan = build_plan(sched, dataset_size=4096, n_workers=8, max_steps=32)
    tcfg = TrainerConfig(
        schedule="B", label_smoothing=0.1,
        grad_sync=GradSyncConfig(strategy="torus2d", comm_dtype=jnp.bfloat16))

    trainer = Trainer(
        mesh=mesh, dp_axes=("dy", "dx"), loss_fn=resnet_loss(cfg, 0.1),
        cfg=tcfg, plan=plan,
        data_fn=lambda i, gb: data.batch(i, gb))
    state = TrainState.create(resnet.init(jax.random.key(0), cfg))
    state, history = trainer.run(state, log=lambda *a: None)

    assert len(history) > 0
    losses_seen = [h["loss"] for h in history]
    assert all(np.isfinite(l) for l in losses_seen)
    # learnable synthetic data: loss must drop from the first record
    assert losses_seen[-1] < losses_seen[0], losses_seen
    # batch-size control actually switched stages
    gbs = {h["global_batch"] for h in history}
    assert gbs == {16, 32}
    assert int(state.step) == 32


@pytest.mark.multidevice
def test_grad_sync_strategies_agree_end_to_end(mesh):
    """One step with torus2d == one step with psum (same data, fp32 comm)."""
    cfg = resnet.ResNetConfig.tiny(num_classes=4, compute_dtype=jnp.float32)
    data = SyntheticImageNet(num_classes=4, image_size=32)
    batch = data.batch(0, 16)
    state0 = TrainState.create(resnet.init(jax.random.key(1), cfg))

    outs = {}
    for strategy in ("psum", "torus2d", "hierarchical", "ring"):
        tcfg = TrainerConfig(grad_sync=GradSyncConfig(
            strategy=strategy, comm_dtype=jnp.float32))
        step = make_train_step(resnet_loss(cfg, 0.1), mesh, ("dy", "dx"),
                               tcfg, donate=False)
        new_state, metrics = step(state0, batch,
                                  jnp.asarray(10.0), jnp.asarray(16.0))
        outs[strategy] = (jax.tree.leaves(new_state.params),
                          float(metrics["loss"]))

    ref_leaves, ref_loss = outs["psum"]
    for strategy in ("torus2d", "hierarchical", "ring"):
        leaves, loss = outs[strategy]
        assert loss == pytest.approx(ref_loss, rel=1e-5)
        for a, b in zip(leaves, ref_leaves):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-5)


@pytest.mark.slow
@pytest.mark.multidevice
def test_transformer_lm_trains_with_recipe(mesh):
    """The paper's technique applied to an assigned arch (qwen3 smoke)."""
    from repro.configs import registry
    cfg = registry.get_smoke("qwen3-1.7b")
    data = SyntheticTokens(vocab=cfg.vocab)

    def loss_fn(params, batch, dp_axes):
        tokens, labels = batch
        logits, aux = T.forward(params, tokens, cfg)
        return losses.label_smoothing_xent(logits, labels, 0.1), aux

    sched = BatchSchedule((BatchStage(0, 4, 2),))
    plan = build_plan(sched, dataset_size=64, n_workers=8, max_steps=12)
    tcfg = TrainerConfig(schedule="B", grad_sync=GradSyncConfig(
        strategy="torus2d", fuse=False, comm_dtype=jnp.bfloat16))
    trainer = Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
                      cfg=tcfg, plan=plan,
                      data_fn=lambda i, gb: data.batch(i, gb, 32))
    state = TrainState.create(T.init(jax.random.key(2), cfg))
    state, history = trainer.run(state, log=lambda *a: None)
    assert history[-1]["loss"] < history[0]["loss"]


def test_checkpoint_roundtrip(tmp_path):
    cfg = resnet.ResNetConfig.tiny()
    state = TrainState.create(resnet.init(jax.random.key(3), cfg))
    path = checkpoint.save(str(tmp_path), state)
    restored = checkpoint.restore(path, state)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert checkpoint.latest(str(tmp_path)) == path


def test_generate_and_batcher():
    from repro.configs import registry
    from repro.serve.decode import RequestBatcher, generate
    cfg = registry.get_smoke("gemma2-27b")
    params = T.init(jax.random.key(4), cfg)
    batcher = RequestBatcher(batch_size=2, seq_len=8)
    prompts, lens, n = batcher.pack([[1, 2, 3], [4, 5]])
    toks = generate(params, prompts, cfg, max_new_tokens=4)
    assert toks.shape == (2, 4)
    assert (np.asarray(toks) >= 0).all() and (np.asarray(toks) < cfg.vocab).all()
    res = batcher.unpack(toks, n)
    assert len(res) == 2
