"""Bucket-size autotuner: analytic knee, cost-model pick, sweep
refinement, and the ``bucket_bytes="auto"`` resolution path."""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, collectives
from repro.core.autotune import (HardwareModel, LEGACY_DEFAULT_BUCKET_BYTES,
                                 TPU_POD_HW, analytic_knee_bytes,
                                 candidate_bucket_bytes, pick_within_bracket,
                                 recommend_bucket_bytes, refine_from_sweep,
                                 sweep_bracket)
from repro.core.grad_sync import (AUTO, GradSyncConfig, bucket_layout,
                                  resolve_sync_config, sync_tree)
from repro.core.topology import TorusGrid

RESNET50_BF16_BYTES = 51e6


# ----------------------------------------------------------- analytic knee

def test_knee_matches_roadmap_formula():
    """For ring-family strategies wire volume ~2x payload, so the knee is
    ~steps * latency * link_bw / 2 (the ROADMAP formula): 16x16 torus2d has
    60 steps -> ~1.5 MB at 50 GB/s, 1 us."""
    knee = analytic_knee_bytes("torus2d", 16, 16, TPU_POD_HW)
    c = collectives.comm_cost_model("torus2d", 1 << 20, 16, 16,
                                    TPU_POD_HW.link_bw, TPU_POD_HW.latency_s)
    expected = c["steps"] * TPU_POD_HW.latency_s * TPU_POD_HW.link_bw \
        / (c["wire_bytes"] / (1 << 20))
    assert knee == int(expected)
    assert 1.0e6 < knee < 2.0e6

def test_knee_scales_with_steps():
    """The flat ring has ~8x the steps of the 2D torus at 256 chips, so its
    knee (latency amortization point) is correspondingly larger."""
    ring = analytic_knee_bytes("ring", 16, 16, TPU_POD_HW)
    torus = analytic_knee_bytes("torus2d", 16, 16, TPU_POD_HW)
    assert ring > 4 * torus


def test_knee_degenerate_grid():
    assert analytic_knee_bytes("psum", 1, 1, TPU_POD_HW) == 0


def test_candidate_grid_brackets_knee_and_clamps():
    cands = candidate_bucket_bytes(1 << 20, total_bytes=3 << 20)
    assert 0 in cands
    assert (1 << 20) in cands
    assert all(b < 3 << 20 for b in cands)
    assert min(b for b in cands if b) == (1 << 20) // 16


# -------------------------------------------------------- cost-model pick

def test_recommend_beats_fused_and_legacy_constant():
    """The acceptance criterion: the pick's exposed comm time beats both
    the unbucketed baseline and the old hand-set 4 MiB constant."""
    rec = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW,
                                 total_bytes=RESNET50_BF16_BYTES)
    assert rec["mode"] == "cost_model"

    def exposed(b):
        return collectives.bucketed_comm_cost_model(
            "torus2d", RESNET50_BF16_BYTES, b, 16, 16, TPU_POD_HW.link_bw,
            TPU_POD_HW.latency_s,
            backward_seconds=TPU_POD_HW.backward_seconds)["exposed_seconds"]

    assert rec["exposed_seconds"] < exposed(0)
    assert rec["exposed_seconds"] < exposed(LEGACY_DEFAULT_BUCKET_BYTES)
    # within the slack band of the candidate optimum by construction
    assert rec["exposed_seconds"] <= 1.05 * rec["best_exposed_seconds"]


def test_recommend_within_10pct_of_dense_grid():
    """The default geometric grid's pick stays within 10% of a much denser
    sweep's optimum -- the guarantee the dryrun sweep gate relies on."""
    rec = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW,
                                 total_bytes=RESNET50_BF16_BYTES)
    dense = [int(b) for b in np.geomspace(1e4, RESNET50_BF16_BYTES - 1, 200)]
    ref = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW,
                                 total_bytes=RESNET50_BF16_BYTES,
                                 candidates=dense)
    assert rec["exposed_seconds"] <= 1.10 * ref["best_exposed_seconds"]


def test_recommend_prefers_fewer_buckets_within_slack():
    rec = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW,
                                 total_bytes=RESNET50_BF16_BYTES)
    # every candidate with fewer buckets than the pick must be outside the
    # slack band (otherwise the tie-break would have chosen it)
    for c in rec["candidates"]:
        if c["num_buckets"] < rec["num_buckets"]:
            assert c["exposed_seconds"] > 1.05 * rec["best_exposed_seconds"]


def test_recommend_analytic_mode_without_total():
    rec = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW)
    assert rec["mode"] == "analytic"
    assert rec["bucket_bytes"] == rec["analytic_knee_bytes"]


def test_recommend_retunes_for_strategy():
    """A downgrade torus2d -> ring multiplies the steps, so the tuned
    bucket size must grow with it (the elastic re-tune property)."""
    torus = recommend_bucket_bytes("torus2d", 16, 16, TPU_POD_HW,
                                   total_bytes=RESNET50_BF16_BYTES)
    ring = recommend_bucket_bytes("ring", 16, 16, TPU_POD_HW,
                                  total_bytes=RESNET50_BF16_BYTES)
    assert ring["bucket_bytes"] > torus["bucket_bytes"]


# ------------------------------------------------------- sweep refinement

def _rows(values):
    return [{"bucket_bytes": b, "exposed_seconds": e, "num_exchanges": i + 1}
            for i, (b, e) in enumerate(values)]


def test_sweep_bracket_and_membership():
    rows = _rows([(0, 5e-3), (1 << 20, 1e-3), (4 << 20, 2e-3),
                  (16 << 20, 4e-3)])
    br = sweep_bracket(rows)
    assert br["best_bucket_bytes"] == 1 << 20
    assert br["low"] == 0 and br["high"] == 4 << 20
    assert pick_within_bracket(1 << 20, br)
    assert pick_within_bracket(4 << 20, br)
    assert not pick_within_bracket(16 << 20, br)
    # edge rows are unbounded on the open side
    br_lo = sweep_bracket(_rows([(0, 1e-3), (1 << 20, 2e-3)]))
    assert br_lo["low"] is None
    assert pick_within_bracket(0, br_lo)


def test_sweep_bracket_requires_rows():
    with pytest.raises(ValueError):
        sweep_bracket([{"bucket_bytes": 0}])


def test_refine_from_sweep_picks_fewest_exchanges_in_slack():
    rows = [{"bucket_bytes": 1 << 20, "exposed_seconds": 1.00e-3,
             "num_exchanges": 50},
            {"bucket_bytes": 4 << 20, "exposed_seconds": 1.03e-3,
             "num_exchanges": 13},
            {"bucket_bytes": 16 << 20, "exposed_seconds": 2.0e-3,
             "num_exchanges": 4}]
    ref = refine_from_sweep(rows, "torus2d", 16, 16, TPU_POD_HW,
                            total_bytes=RESNET50_BF16_BYTES)
    assert ref["bucket_bytes"] == 4 << 20   # within 5% of best, 13 < 50
    assert ref["analytic"]["mode"] == "cost_model"
    assert isinstance(ref["agrees"], bool)


# ------------------------------------------- resolve_sync_config("auto")

def _mesh_grid():
    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    return mesh, TorusGrid(h_axes=("dx",), v_axes=("dy",))


def _tree():
    rng = np.random.RandomState(0)
    return {f"layer{i:02d}": {
        "kernel": jnp.asarray(rng.randn(128, 64), jnp.float32),
        "bias": jnp.asarray(rng.randn(64), jnp.float32)}
        for i in range(6)}


def test_resolve_auto_produces_tuned_int_and_event():
    mesh, grid = _mesh_grid()
    tree = _tree()
    cfg = GradSyncConfig(strategy="torus2d", fuse=True,
                         comm_dtype=jnp.float32, bucket_bytes=AUTO)
    rcfg, events = resolve_sync_config(cfg, grid, mesh, mesh.axis_names,
                                       params_like=tree)
    assert isinstance(rcfg.bucket_bytes, int) and rcfg.bucket_bytes > 0
    tune = [e for e in events if e["event"] == "bucket_autotune"]
    assert len(tune) == 1
    assert tune[0]["mode"] == "cost_model"
    assert tune[0]["bucket_bytes"] == rcfg.bucket_bytes
    assert tune[0]["strategy"] == "torus2d"
    # layout is now computable (would raise on the unresolved sentinel)
    assert bucket_layout(tree, rcfg)


def test_resolve_auto_without_params_uses_knee():
    mesh, grid = _mesh_grid()
    cfg = GradSyncConfig(strategy="torus2d", fuse=True, bucket_bytes=AUTO)
    rcfg, events = resolve_sync_config(cfg, grid, mesh, mesh.axis_names)
    hw = TPU_POD_HW
    x, y = grid.sizes(mesh)
    assert rcfg.bucket_bytes == analytic_knee_bytes("torus2d", x, y, hw)
    assert events[-1]["mode"] == "analytic"


def test_resolve_auto_retunes_on_downgrade():
    """An elastic downgrade (down torus axis -> ring fallback... on the
    2x4 mesh torus2d dies when 'dy' is down) must re-tune bucket_bytes for
    the surviving strategy, not reuse the torus2d-tuned value."""
    mesh, grid = _mesh_grid()
    tree = _tree()
    cfg = GradSyncConfig(strategy="torus2d", fuse=True,
                         comm_dtype=jnp.float32, bucket_bytes=AUTO)
    healthy, _ = resolve_sync_config(cfg, grid, mesh, mesh.axis_names)
    degraded, events = resolve_sync_config(
        cfg, grid, mesh, mesh.axis_names, down_axes=("dy",),
        context="elastic", params_like=tree)
    assert degraded.strategy != "torus2d"
    tune = [e for e in events if e["event"] == "bucket_autotune"]
    assert tune and tune[0]["strategy"] == degraded.strategy
    assert tune[0]["context"] == "elastic"
    assert isinstance(degraded.bucket_bytes, int)
    # different schedule, different knee -> different tuned size
    assert degraded.bucket_bytes != healthy.bucket_bytes


def test_sync_tree_rejects_unresolved_auto():
    mesh, grid = _mesh_grid()
    cfg = GradSyncConfig(bucket_bytes=AUTO)
    with pytest.raises(ValueError, match="resolve_sync_config"):
        sync_tree({"w": jnp.zeros((64,))}, grid, cfg)
    with pytest.raises(ValueError, match="resolve_sync_config"):
        bucket_layout({"w": jnp.zeros((64,))}, cfg)


def test_hardware_model_per_mesh_defaults():
    from repro.configs import comm
    hw1 = comm.hw_for_mesh("pod16x16")
    hw2 = comm.hw_for_mesh("pod2x16x16")
    assert hw1.link_bw > hw2.link_bw       # inter-pod links are slower
    assert hw2.latency_s > hw1.latency_s
    assert comm.hw_for_mesh("unknown") == hw1
    hw3 = comm.hw_for_mesh("pod16x16", backward_seconds=0.1)
    assert hw3.backward_seconds == 0.1
    assert comm.default_bucket_bytes("qwen3-1.7b") == AUTO
    assert comm.default_bucket_bytes("llama3-405b", fsdp=True) == 0
    assert comm.backward_seconds_estimate(0, 0) > 0
    est = comm.backward_seconds_estimate(1e16, 256)
    assert 0 < est < 1
