"""LARS, schedules, losses, batch-size control."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.core import lars, losses, schedules
from repro.core.batch_control import build_plan, epoch_of
from repro.core.schedules import BatchSchedule, BatchStage, ConfigA, ConfigB, paper_schedule


# ----------------------------------------------------------------- LARS ----

def _tree():
    rng = np.random.RandomState(0)
    return {"dense": {"kernel": jnp.asarray(rng.randn(8, 4), jnp.float32),
                      "bias": jnp.asarray(rng.randn(4), jnp.float32)}}


def test_lars_trust_ratio_scales_update():
    params = _tree()
    grads = jax.tree.map(jnp.ones_like, params)
    opt = lars.init(params)
    cfg = lars.LARSConfig(weight_decay=0.0)
    new_p, new_opt = lars.update(params, grads, opt, lr=1.0, momentum=0.0, cfg=cfg)
    # kernel: step = eta * ||w||/||g|| * g  (wd=0)
    w = params["dense"]["kernel"]
    g = grads["dense"]["kernel"]
    trust = cfg.eta * jnp.linalg.norm(w) / (jnp.linalg.norm(g) + cfg.eps)
    np.testing.assert_allclose(np.asarray(new_p["dense"]["kernel"]),
                               np.asarray(w - trust * g), rtol=1e-6)
    # bias: skip-listed -> plain SGD step of lr * g
    np.testing.assert_allclose(np.asarray(new_p["dense"]["bias"]),
                               np.asarray(params["dense"]["bias"] - 1.0), rtol=1e-6)


def test_lars_momentum_accumulates():
    params = _tree()
    grads = jax.tree.map(jnp.ones_like, params)
    opt = lars.init(params)
    p1, opt1 = lars.update(params, grads, opt, lr=0.1, momentum=0.9)
    p2, opt2 = lars.update(p1, grads, opt1, lr=0.1, momentum=0.9)
    v1 = opt1["momentum"]["dense"]["kernel"]
    v2 = opt2["momentum"]["dense"]["kernel"]
    assert np.all(np.abs(np.asarray(v2)) > np.abs(np.asarray(v1)) * 0.99)


def test_lars_bf16_params_fp32_master_math():
    params = jax.tree.map(lambda x: x.astype(jnp.bfloat16), _tree())
    grads = jax.tree.map(jnp.ones_like, params)
    opt = lars.init(params)
    new_p, _ = lars.update(params, grads, opt, lr=0.1, momentum=0.9)
    assert new_p["dense"]["kernel"].dtype == jnp.bfloat16
    assert opt["momentum"]["dense"]["kernel"].dtype == jnp.float32


# ------------------------------------------------------------ schedules ----

def test_config_a_warmup_and_decay():
    a = ConfigA()
    assert float(a.lr(0.0)) == pytest.approx(1e-5)
    assert float(a.lr(34.0)) == pytest.approx(34.0, rel=1e-3)
    assert float(a.lr(90.0)) == pytest.approx(0.0, abs=1e-6)
    assert float(a.lr(50.0)) < 34.0


def test_config_b_matches_paper_formula():
    b = ConfigB()
    assert float(b.lr(0.0)) == pytest.approx(0.2)
    assert float(b.lr(5.0)) == pytest.approx(29.0 * (1 - 5 / 90) ** 2, rel=0.08)
    assert float(b.lr(20.0)) == pytest.approx(29.0 * (1 - 20 / 90) ** 2, rel=1e-5)
    assert float(b.lr(60.0)) == pytest.approx(50.0 * (1 - 60 / 90) ** 2, rel=1e-5)


def test_config_b_momentum_noise_scale_anchor():
    b = ConfigB()
    # at the reference batch the momentum must be the reference momentum
    assert float(b.mom(10.0, 32 * 1024)) == pytest.approx(0.9, rel=1e-6)
    # larger batch -> larger momentum (constant noise scale)
    assert float(b.mom(10.0, 54 * 1024)) > 0.9
    assert float(b.mom(10.0, 119 * 1024)) > float(b.mom(10.0, 54 * 1024))


# --------------------------------------------------------------- losses ----

def test_label_smoothing_reduces_confident_gradient():
    logits = jnp.asarray([[10.0, -10.0, -10.0]])
    labels = jnp.asarray([0])
    plain = float(losses.softmax_xent(logits, labels))
    smooth = float(losses.label_smoothing_xent(logits, labels, smoothing=0.1))
    assert smooth > plain  # smoothing penalizes over-confidence


@settings(max_examples=25, deadline=None)
@given(b=st.integers(1, 6), k=st.integers(2, 30), seed=st.integers(0, 999),
       alpha=st.floats(0.0, 0.3))
def test_ls_xent_property_matches_manual(b, k, seed, alpha):
    rng = np.random.RandomState(seed)
    logits = rng.randn(b, k).astype(np.float32) * 3
    labels = rng.randint(0, k, size=(b,))
    got = np.asarray(losses.ls_xent_ref(jnp.asarray(logits), jnp.asarray(labels), alpha))
    # manual: -sum q log p with q = (1-a) onehot + a/k
    logp = np.log(np.exp(logits - logits.max(-1, keepdims=True)) /
                  np.exp(logits - logits.max(-1, keepdims=True)).sum(-1, keepdims=True))
    q = np.full((b, k), alpha / k)
    q[np.arange(b), labels] += 1 - alpha
    want = -(q * logp).sum(-1)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_masked_loss():
    logits = jnp.zeros((4, 10))
    labels = jnp.zeros((4,), jnp.int32)
    mask = jnp.asarray([True, True, False, False])
    full = float(losses.label_smoothing_xent(logits, labels, 0.1))
    masked = float(losses.label_smoothing_xent(logits, labels, 0.1, where=mask))
    assert full == pytest.approx(masked, rel=1e-6)  # uniform logits -> same


# -------------------------------------------------------- batch control ----

def test_paper_exp4_schedule_stages():
    sched = paper_schedule("exp4")
    assert len(sched.stages) == 4
    assert sched.stages[0].per_worker_batch == 16
    assert sched.stages[-1].per_worker_batch == 32
    assert sched.total_epochs == 90


def test_plan_steps_and_epochs():
    sched = BatchSchedule((BatchStage(0, 1, 16), BatchStage(1, 2, 32)))
    plan = build_plan(sched, dataset_size=1280, n_workers=4)
    assert plan.stages[0].global_batch == 64
    assert plan.stages[0].num_steps == 20     # 1 epoch * 1280 / 64
    assert plan.stages[1].num_steps == 10     # 1 epoch * 1280 / 128
    e = epoch_of(plan, plan.stages[1], 5)
    assert e == pytest.approx(1.5)


def test_plan_max_steps_truncation():
    plan = build_plan(paper_schedule("exp1"), dataset_size=1_281_167,
                      n_workers=2176, max_steps=100)
    assert plan.total_steps == 100
