"""Fault-tolerance layer (docs/robustness.md): chaos-driven training runs,
non-finite-gradient guards + dynamic loss scale, crash-consistent
checkpointing, resume, and graceful grad-sync degradation."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.grad_sync import (GradSyncConfig, fallback_chain,
                                  resolve_sync_config)
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.core.topology import select_grid
from repro.data.synthetic import SyntheticImageNet
from repro.models import resnet
from repro.testing.chaos import FaultPlan, TransientDataError
from repro.train import checkpoint
from repro.train.state import TrainState
from repro.train.trainer import (GuardConfig, Trainer, TrainerConfig,
                                 make_train_step)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("dy", "dx"))


CFG = resnet.ResNetConfig.tiny(num_classes=4)
DATA = SyntheticImageNet(num_classes=4, image_size=32, noise=0.3)


def resnet_loss(params, batch, dp_axes):
    images, labels = batch
    logits = resnet.apply(params, images, CFG, dp_axes=dp_axes)
    return losses.label_smoothing_xent(
        logits, labels, 0.1), jnp.zeros((), jnp.float32)


def make_trainer(mesh, *, max_steps, ckpt_dir=None, fault_plan=None,
                 strategy="torus2d", ckpt_every=0, guard=GuardConfig()):
    sched = BatchSchedule((BatchStage(0, 1.0, 2),))
    plan = build_plan(sched, dataset_size=256, n_workers=8,
                      max_steps=max_steps)
    tcfg = TrainerConfig(
        grad_sync=GradSyncConfig(strategy=strategy), guard=guard,
        log_every=1000, ckpt_every_steps=ckpt_every,
        retry_backoff_s=1e-4)
    return Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=resnet_loss,
                   cfg=tcfg, plan=plan,
                   data_fn=lambda i, gb: DATA.batch(i, gb),
                   checkpoint_dir=ckpt_dir, fault_plan=fault_plan)


def fresh_state(loss_scale=1.0):
    return TrainState.create(resnet.init(jax.random.key(0), CFG),
                             loss_scale=loss_scale)


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Tentpole acceptance: chaos run across >= 3 fault classes, bit-identical
# to a fault-free run (skipped steps excluded)
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.multidevice
def test_chaos_run_bit_identical_to_fault_free(mesh, tmp_path):
    """Four injected fault classes -- transient data_fn failures, a
    checkpoint write crashed mid-file, a down torus axis, and non-finite
    gradients -- and the run must still produce params bit-identical to a
    fault-free run of the same seed.

    The non-finite steps are the tail of the plan so "skipped steps
    excluded" is exact: a skipped step must be a true no-op on params and
    momentum, so the 10-step faulted run (last 2 skipped) matches the
    8-step clean run bit for bit. The down axis degrades torus2d -> ring,
    so the clean reference uses ring explicitly to share the schedule.
    """
    faults = FaultPlan(
        nan_grad_steps=(8,), inf_grad_steps=(9,),
        data_fail_steps=(2, 5), ckpt_crash_writes=(0,),
        down_axes=("dy",))
    trainer = make_trainer(mesh, max_steps=10, ckpt_dir=str(tmp_path),
                           fault_plan=faults, strategy="torus2d",
                           ckpt_every=4)
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert int(state.step) == 10

    ref = make_trainer(mesh, max_steps=8, strategy="ring")
    ref_state, _ = ref.run(fresh_state(), max_steps=8, log=lambda *a: None)

    assert_trees_equal(state.params, ref_state.params)
    assert_trees_equal(state.opt_state, ref_state.opt_state)

    # every recovery is visible in history
    events = [h["event"] for h in history if "event" in h]
    assert "grad_sync_downgrade" in events
    assert "data_retry" in events
    assert "checkpoint_retry" in events
    assert "checkpoint" in events
    downgrade = next(h for h in history
                     if h.get("event") == "grad_sync_downgrade")
    assert (downgrade["from"], downgrade["to"]) == ("torus2d", "ring")
    skipped = [h for h in history if h.get("skipped")]
    assert [h["step"] for h in skipped] == [9, 10]
    assert all(h["nonfinite_count"] > 0 for h in skipped)

    # the crashed+retried checkpoints on disk are all valid and restorable
    best = checkpoint.latest_valid(str(tmp_path), like=state)
    assert best is not None
    assert_trees_equal(checkpoint.restore(best, state).params, state.params)


@pytest.mark.multidevice
def test_nonfinite_guard_skips_update_and_rescales(mesh):
    """Unit-level guard semantics: skip is a param/momentum no-op, the loss
    scale halves per skip and regrows after growth_interval clean steps."""
    tcfg = TrainerConfig(
        grad_sync=GradSyncConfig(strategy="psum"),
        guard=GuardConfig(init_scale=4.0, growth_interval=2,
                          growth_factor=2.0, backoff_factor=0.5,
                          max_scale=8.0))
    step = make_train_step(resnet_loss, mesh, ("dy", "dx"), tcfg,
                           donate=False)
    state = fresh_state(loss_scale=4.0)
    good = DATA.batch(0, 16)
    bad = FaultPlan(nan_grad_steps=(0,)).corrupt_batch(0, good)
    ep, gb = jnp.asarray(0.0), jnp.asarray(16.0)

    s1, m1 = step(state, bad, ep, gb)
    assert int(m1["skipped"]) == 1 and int(m1["nonfinite_count"]) > 0
    assert_trees_equal(s1.params, state.params)        # true no-op
    assert_trees_equal(s1.opt_state, state.opt_state)
    assert float(s1.loss_scale) == 2.0                 # backed off
    assert int(s1.step) == 1                           # step still counts

    s2, m2 = step(s1, good, ep, gb)
    assert int(m2["skipped"]) == 0
    assert float(s2.loss_scale) == 2.0                 # 1 clean step: hold
    s3, _ = step(s2, good, ep, gb)
    assert float(s3.loss_scale) == 4.0                 # 2 clean: regrow
    assert int(s3.good_steps) == 0                     # counter reset


@pytest.mark.multidevice
def test_guarded_step_is_bit_identical_when_clean(mesh):
    """GuardConfig(init_scale=1.0) must not perturb clean-step numerics."""
    batch = DATA.batch(0, 16)
    ep, gb = jnp.asarray(0.5), jnp.asarray(16.0)
    outs = {}
    for enabled in (True, False):
        tcfg = TrainerConfig(grad_sync=GradSyncConfig(strategy="torus2d"),
                             guard=GuardConfig(enabled=enabled))
        step = make_train_step(resnet_loss, mesh, ("dy", "dx"), tcfg,
                               donate=False)
        outs[enabled], _ = step(fresh_state(), batch, ep, gb)
    assert_trees_equal(outs[True].params, outs[False].params)
    assert_trees_equal(outs[True].opt_state, outs[False].opt_state)


@pytest.mark.multidevice
def test_fp16_style_guard_settles_at_high_scale(mesh):
    """The paper trains in fp16 with loss scaling; our fp16-style config
    starts at the standard ``init_scale=2**15``. With clean numerics the
    guard must never skip and the scale must settle at (not below) init --
    regrowth attempts every ``growth_interval`` clean steps are capped at
    ``max_scale``, never a sawtooth of overflow/backoff."""
    guard = GuardConfig(init_scale=2.0 ** 15, growth_interval=4)
    trainer = make_trainer(mesh, max_steps=12, guard=guard)
    state, history = trainer.run(fresh_state(loss_scale=guard.init_scale),
                                 log=lambda *a: None)
    assert int(state.step) == 12
    assert [h for h in history if h.get("skipped")] == []
    assert float(state.loss_scale) >= guard.init_scale


# ---------------------------------------------------------------------------
# Graceful grad-sync degradation
# ---------------------------------------------------------------------------

def test_fallback_chains_end_in_psum():
    for strategy in ("torus2d", "hierarchical", "ring", "psum"):
        chain = fallback_chain(strategy)
        assert chain[0] == strategy and chain[-1] == "psum"
    assert fallback_chain("unknown") == ("unknown", "psum")


@pytest.mark.multidevice
def test_resolve_keeps_viable_strategy(mesh):
    grid = select_grid(("dy", "dx"))
    cfg, events = resolve_sync_config(GradSyncConfig(strategy="torus2d"),
                                      grid, mesh, ("dy", "dx"))
    assert cfg.strategy == "torus2d" and events == []


@pytest.mark.multidevice
def test_resolve_degrades_on_down_axis(mesh):
    grid = select_grid(("dy", "dx"))
    cfg, events = resolve_sync_config(GradSyncConfig(strategy="torus2d"),
                                      grid, mesh, ("dy", "dx"),
                                      down_axes=("dy",))
    assert cfg.strategy == "ring"
    rejected = [e["strategy"] for e in events
                if e["event"] == "grad_sync_strategy_rejected"]
    assert rejected == ["torus2d", "hierarchical"]
    assert events[-1] == {"event": "grad_sync_downgrade",
                          "from": "torus2d", "to": "ring",
                          "context": "startup"}
    # explicit ppermute ring pins dead neighbor links -> psum
    cfg2, _ = resolve_sync_config(
        GradSyncConfig(strategy="torus2d", lowering="ring"), grid, mesh,
        ("dy", "dx"), down_axes=("dy",))
    assert cfg2.strategy == "psum"


# ---------------------------------------------------------------------------
# Step-fn build: one builder call for a multi-stage plan (regression)
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_single_step_fn_across_stages(mesh, monkeypatch):
    """The old per-global-batch cache stored identical fns (the builder
    never saw the batch size); now the step fn is built exactly once and
    jit specializes per stage shape."""
    import repro.train.trainer as trainer_mod
    calls = []
    real = trainer_mod.make_train_step

    def counting(*a, **kw):
        calls.append(a)
        return real(*a, **kw)

    monkeypatch.setattr(trainer_mod, "make_train_step", counting)
    sched = BatchSchedule((BatchStage(0, 0.125, 2), BatchStage(0.125, 0.25, 4)))
    plan = build_plan(sched, dataset_size=256, n_workers=8, max_steps=4)
    trainer = Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=resnet_loss,
                      cfg=TrainerConfig(log_every=1000), plan=plan,
                      data_fn=lambda i, gb: DATA.batch(i, gb))
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert len(calls) == 1
    assert {h["global_batch"] for h in history if "global_batch" in h} \
        == {16, 32}


# ---------------------------------------------------------------------------
# Crash-consistent checkpointing
# ---------------------------------------------------------------------------

def two_states():
    s5 = fresh_state()
    s5 = TrainState(s5.params, s5.opt_state, jnp.asarray(5, jnp.int32),
                    s5.loss_scale, s5.good_steps)
    s10 = TrainState(jax.tree.map(lambda x: x + 1, s5.params), s5.opt_state,
                     jnp.asarray(10, jnp.int32), s5.loss_scale, s5.good_steps)
    return s5, s10


def test_latest_orders_by_step_not_mtime(tmp_path):
    """Regression: mtime ordering picks the wrong file for copied/restored
    checkpoints; `latest` must order by manifest step."""
    s5, s10 = two_states()
    p10 = checkpoint.save(str(tmp_path), s10)
    p5 = checkpoint.save(str(tmp_path), s5)      # later mtime, older step
    os.utime(p10, (1, 1))                        # make step-10 look ancient
    assert checkpoint.latest(str(tmp_path)) == p10
    # an old checkpoint copied back in (fresh mtime, step 5 in its
    # manifest) never shadows the true newest
    for src in (p5, checkpoint.manifest_path(p5)):
        shutil.copy(src, str(tmp_path) + "/" +
                    os.path.basename(src).replace("step_", "restored_"))
    assert checkpoint.latest(str(tmp_path)) == p10


def test_checkpoint_roundtrip_preserves_guard_state(tmp_path):
    state = fresh_state(loss_scale=8.0)
    path = checkpoint.save(str(tmp_path), state)
    restored = checkpoint.restore(path, state)
    assert_trees_equal(restored.params, state.params)
    assert float(restored.loss_scale) == 8.0
    manifest = checkpoint.validate(path, like=state)
    assert manifest["step"] == 0 and manifest["format_version"] == 1


def test_truncated_checkpoint_rejected_with_fallback(tmp_path):
    """A truncated npz is rejected with a clear error and latest_valid
    falls back to the previous valid checkpoint."""
    s5, s10 = two_states()
    p5 = checkpoint.save(str(tmp_path), s5)
    p10 = checkpoint.save(str(tmp_path), s10)
    with open(p10, "r+b") as f:                  # truncate mid-payload
        f.truncate(os.path.getsize(p10) // 2)
    with pytest.raises(checkpoint.CheckpointCorruptError,
                       match="unreadable payload|CRC"):
        checkpoint.restore(p10, s10)
    skipped = []
    best = checkpoint.latest_valid(str(tmp_path), like=s5,
                                   on_skip=lambda p, r: skipped.append(p))
    assert best == p5 and skipped == [p10]
    restored = checkpoint.restore(best, s5)
    assert int(restored.step) == 5
    assert_trees_equal(restored.params, s5.params)


def test_bitflip_detected_by_crc(tmp_path):
    state = fresh_state()
    path = checkpoint.save(str(tmp_path), state)
    data = bytearray(open(path, "rb").read())
    data[len(data) // 2] ^= 0xFF                 # flip a payload bit
    open(path, "wb").write(bytes(data))
    with pytest.raises(checkpoint.CheckpointCorruptError):
        checkpoint.validate(path)


def test_crashed_write_leaves_no_torso(tmp_path):
    """A crash mid-write (injected OSError before rename, retries
    exhausted) must leave the directory exactly as it was: the previous
    checkpoint intact, no tmp files, no uncommitted npz."""
    s5, s10 = two_states()
    p5 = checkpoint.save(str(tmp_path), s5)
    plan = FaultPlan(ckpt_crash_writes=(0,), ckpt_crashes_per_write=99)
    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.save(str(tmp_path), s10, retries=2, backoff_s=1e-4,
                        io_hook=plan.checkpoint_io_hook)
    names = sorted(os.listdir(str(tmp_path)))
    assert names == sorted([os.path.basename(p5),
                            os.path.basename(checkpoint.manifest_path(p5))])
    checkpoint.validate(p5, like=s5)             # survivor still valid


def test_retention_prunes_oldest(tmp_path):
    states = []
    base = fresh_state()
    for step in (1, 2, 3, 4):
        st = TrainState(base.params, base.opt_state,
                        jnp.asarray(step, jnp.int32), base.loss_scale,
                        base.good_steps)
        states.append(checkpoint.save(str(tmp_path), st, keep_last=2))
    left = sorted(f for f in os.listdir(str(tmp_path)) if f.endswith(".npz"))
    assert left == ["step_00000003.npz", "step_00000004.npz"]
    assert checkpoint.latest(str(tmp_path)).endswith("step_00000004.npz")


def test_save_retries_transient_io_errors(tmp_path):
    state = fresh_state()
    plan = FaultPlan(ckpt_crash_writes=(0,), ckpt_crashes_per_write=2)
    attempts = []
    path = checkpoint.save(str(tmp_path), state, retries=3, backoff_s=1e-4,
                           io_hook=plan.checkpoint_io_hook,
                           on_retry=lambda a, e: attempts.append(a))
    assert attempts == [0, 1]                    # two crashes, then success
    checkpoint.validate(path, like=state)


# ---------------------------------------------------------------------------
# Resume: bit-exact params after interrupt + resume vs uninterrupted run
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.multidevice
def test_resume_midstage_bit_exact(mesh, tmp_path):
    straight = make_trainer(mesh, max_steps=10)
    ref, _ = straight.run(fresh_state(), log=lambda *a: None)

    part = make_trainer(mesh, max_steps=10, ckpt_dir=str(tmp_path),
                        ckpt_every=4)
    part.run(fresh_state(), max_steps=7, log=lambda *a: None)   # "crash" at 7

    resumed_tr = make_trainer(mesh, max_steps=10, ckpt_dir=str(tmp_path),
                              ckpt_every=4)
    resumed, history = resumed_tr.run(fresh_state(), resume=True,
                                      log=lambda *a: None)
    ev = next(h for h in history if h.get("event") == "resume")
    assert ev["step"] == 4          # newest valid ckpt was step 4 (not 7)
    assert int(resumed.step) == 10
    assert_trees_equal(resumed.params, ref.params)
    assert_trees_equal(resumed.opt_state, ref.opt_state)


@pytest.mark.multidevice
def test_resume_skips_corrupt_newest(mesh, tmp_path):
    part = make_trainer(mesh, max_steps=6, ckpt_dir=str(tmp_path),
                        ckpt_every=2)
    part.run(fresh_state(), log=lambda *a: None)
    newest = checkpoint.latest(str(tmp_path))
    with open(newest, "r+b") as f:
        f.truncate(100)
    resumed_tr = make_trainer(mesh, max_steps=6, ckpt_dir=str(tmp_path))
    _, history = resumed_tr.run(fresh_state(), resume=True,
                                log=lambda *a: None)
    kinds = [h.get("event") for h in history if "event" in h]
    assert "checkpoint_rejected" in kinds
    ev = next(h for h in history if h.get("event") == "resume")
    assert ev["step"] == 4          # fell back past the corrupt step-6 file


# ---------------------------------------------------------------------------
# Data-pipeline transient failures
# ---------------------------------------------------------------------------

@pytest.mark.multidevice
def test_data_failures_exhaust_retries(mesh):
    faults = FaultPlan(data_fail_steps=(1,), data_failures_per_step=99)
    trainer = make_trainer(mesh, max_steps=3, fault_plan=faults)
    with pytest.raises(RuntimeError, match="data_fn failed at step 1"):
        trainer.run(fresh_state(), log=lambda *a: None)


def test_fault_plan_determinism():
    plan_a = FaultPlan.random(7, 100)
    plan_b = FaultPlan.random(7, 100)
    assert plan_a.nan_grad_steps == plan_b.nan_grad_steps
    assert plan_a.data_fail_steps == plan_b.data_fail_steps
    wrapped = plan_a.wrap_data_fn(lambda i, gb: "ok")
    step = plan_a.data_fail_steps[0]
    with pytest.raises(TransientDataError):
        wrapped(step, 16)
    assert wrapped(step, 16) == "ok"             # transient: retry succeeds
