"""Elastic self-healing layer (docs/robustness.md "Elastic recovery"):
permanent-failure detection, mid-run strategy re-resolution + checkpoint
rollback, and the async off-thread checkpoint writer."""

import os
import shutil
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import losses
from repro.core.grad_sync import GradSyncConfig
from repro.core.schedules import BatchSchedule, BatchStage
from repro.core.batch_control import build_plan
from repro.data.synthetic import SyntheticImageNet
from repro.models import resnet
from repro.testing.chaos import FaultPlan
from repro.train import checkpoint
from repro.train.checkpoint import AsyncCheckpointWriter
from repro.train.elastic import ElasticConfig, PermanentFailure, Supervisor
from repro.train.state import TrainState
from repro.train.trainer import GuardConfig, Trainer, TrainerConfig


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((2, 4), ("dy", "dx"))


CFG = resnet.ResNetConfig.tiny(num_classes=4)
DATA = SyntheticImageNet(num_classes=4, image_size=32, noise=0.3)


def resnet_loss(params, batch, dp_axes):
    images, labels = batch
    logits = resnet.apply(params, images, CFG, dp_axes=dp_axes)
    return losses.label_smoothing_xent(
        logits, labels, 0.1), jnp.zeros((), jnp.float32)


def make_trainer(mesh, *, max_steps, ckpt_dir=None, fault_plan=None,
                 strategy="torus2d", ckpt_every=0,
                 elastic=ElasticConfig(), ckpt_async=True, keep_last=10):
    sched = BatchSchedule((BatchStage(0, 1.0, 2),))
    plan = build_plan(sched, dataset_size=256, n_workers=8,
                      max_steps=max_steps)
    tcfg = TrainerConfig(
        grad_sync=GradSyncConfig(strategy=strategy), guard=GuardConfig(),
        log_every=1000, ckpt_every_steps=ckpt_every,
        ckpt_keep_last=keep_last, ckpt_async=ckpt_async,
        retry_backoff_s=1e-4, elastic=elastic)
    return Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=resnet_loss,
                   cfg=tcfg, plan=plan,
                   data_fn=lambda i, gb: DATA.batch(i, gb),
                   checkpoint_dir=ckpt_dir, fault_plan=fault_plan)


def fresh_state():
    return TrainState.create(resnet.init(jax.random.key(0), CFG))


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def events_of(history, kind):
    return [h for h in history if h.get("event") == kind]


# ---------------------------------------------------------------------------
# Supervisor unit semantics (pure python, no mesh)
# ---------------------------------------------------------------------------

def test_supervisor_axis_down_detection():
    sup = Supervisor(ElasticConfig(), initial_down_axes=("dz",))
    plan = FaultPlan(down_axes=("dz",), axis_down_events=(("dy", 5),))
    assert sup.check_health(4, plan) is None        # dz already known
    failure = sup.check_health(5, plan)
    assert isinstance(failure, PermanentFailure)
    assert failure.kind == "axis_down"
    assert failure.down_axes == ("dy",) and failure.step == 5
    sup.start_recovery(failure)
    assert sup.down_axes == ("dy", "dz")
    assert sup.check_health(6, plan) is None        # dy now known too


def test_supervisor_streak_thresholds_and_reset():
    cfg = ElasticConfig(max_consecutive_nonfinite=3,
                        max_consecutive_timeouts=2)
    sup = Supervisor(cfg)
    assert sup.observe_step(0, skipped=True) is None
    assert sup.observe_step(1, skipped=True) is None
    assert not sup.healthy
    assert sup.observe_step(2, skipped=False) is None   # streak broken
    assert sup.healthy
    assert sup.observe_step(3, skipped=True) is None
    assert sup.observe_step(4, skipped=True) is None
    failure = sup.observe_step(5, skipped=True)
    assert failure is not None and failure.kind == "nonfinite_streak"
    sup.start_recovery(failure)
    assert sup.healthy                                  # streaks reset
    assert sup.observe_step(6, skipped=False, timed_out=True) is None
    timeout = sup.observe_step(7, skipped=False, timed_out=True)
    assert timeout is not None and timeout.kind == "timeout"


def test_supervisor_wall_clock_timeout_and_budget():
    cfg = ElasticConfig(max_consecutive_timeouts=1, step_timeout_s=0.5,
                        max_recoveries=1)
    sup = Supervisor(cfg)
    assert sup.observe_step(0, skipped=False, elapsed_s=0.4) is None
    failure = sup.observe_step(1, skipped=False, elapsed_s=0.9)
    assert failure is not None and failure.kind == "timeout"
    assert not sup.exhausted
    assert sup.start_recovery(failure) == 1
    assert sup.exhausted
    disabled = Supervisor(ElasticConfig(enabled=False))
    assert disabled.observe_step(0, skipped=True, timed_out=True) is None
    assert disabled.check_health(0, FaultPlan(down_axes=("dy",))) is None


def test_fault_plan_permanent_signals():
    plan = FaultPlan(axis_down_events=(("dy", 3), ("dx", 7)),
                     timeout_steps=(4,), timeouts_per_step=2)
    assert plan.down_axes_at(2) == ()
    assert plan.down_axes_at(3) == ("dy",)
    assert plan.down_axes_at(7) == ("dx", "dy")
    assert not plan.step_timed_out(3)
    assert plan.step_timed_out(4) and plan.step_timed_out(4)
    assert not plan.step_timed_out(4)       # consumed: replay runs clean
    once = FaultPlan(nan_grad_steps=(1,), grad_fault_once=True)
    batch = (jnp.ones((4, 2)), jnp.zeros((4,), jnp.int32))
    poisoned = once.corrupt_batch(1, batch)
    assert not bool(jnp.isfinite(poisoned[0]).all())
    replay = once.corrupt_batch(1, batch)
    assert bool(jnp.isfinite(replay[0]).all())


# ---------------------------------------------------------------------------
# Tentpole acceptance: permanent axis loss mid-run -> downgrade + rollback
# -> completion, bit-exact vs a direct run of the degraded strategy from
# the last valid checkpoint
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.multidevice
def test_permanent_axis_loss_recovers_bit_exact(mesh, tmp_path):
    """Axis "dy" dies permanently at step 6. The run must (1) complete all
    10 steps in-process, (2) emit a *mid-run* torus2d->ring downgrade, and
    (3) end bit-identical to a run launched with ring directly from the
    last valid checkpoint (step 4)."""
    run_dir = str(tmp_path / "run")
    faults = FaultPlan(axis_down_events=(("dy", 6),))
    trainer = make_trainer(mesh, max_steps=10, ckpt_dir=run_dir,
                           fault_plan=faults, ckpt_every=4)
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert int(state.step) == 10

    failure = events_of(history, "elastic_failure")
    assert len(failure) == 1
    assert failure[0]["kind"] == "axis_down" and failure[0]["step"] == 6
    assert failure[0]["down_axes"] == ["dy"]
    recovery = events_of(history, "elastic_recovery")
    assert len(recovery) == 1
    assert recovery[0]["step"] == 4 and recovery[0]["attempt"] == 1
    downgrade = events_of(history, "grad_sync_downgrade")
    assert len(downgrade) == 1
    assert (downgrade[0]["from"], downgrade[0]["to"]) == ("torus2d", "ring")
    # the downgrade happened MID-RUN: context says it came from the elastic
    # re-resolution, and it follows the step-6 failure in the event stream
    # (the startup resolution, with no axis down yet, emitted nothing)
    assert downgrade[0]["context"] == "elastic"
    assert history.index(downgrade[0]) > history.index(failure[0])

    # reference: ring from the last valid checkpoint, in a fresh dir
    ref_dir = str(tmp_path / "ref")
    os.makedirs(ref_dir)
    ckpt4 = os.path.join(run_dir, "step_00000004.npz")
    for src in (ckpt4, checkpoint.manifest_path(ckpt4)):
        shutil.copy(src, ref_dir)
    ref = make_trainer(mesh, max_steps=10, ckpt_dir=ref_dir,
                       strategy="ring", ckpt_every=4)
    ref_state, ref_history = ref.run(fresh_state(), resume=True,
                                     log=lambda *a: None)
    assert events_of(ref_history, "resume")[0]["step"] == 4
    assert int(ref_state.step) == 10
    assert_trees_equal(state.params, ref_state.params)
    assert_trees_equal(state.opt_state, ref_state.opt_state)


@pytest.mark.slow
@pytest.mark.multidevice
def test_nonfinite_streak_rollback_bit_exact(mesh, tmp_path):
    """An unbroken NaN streak (sick node) crosses the permanence threshold;
    the run rolls back to the pre-streak checkpoint and — because the
    replay is clean (node replaced: grad_fault_once) — finishes
    bit-identical to a fault-free run: no update is lost to the skips."""
    faults = FaultPlan(nan_grad_steps=(5, 6, 7), grad_fault_once=True)
    trainer = make_trainer(
        mesh, max_steps=10, ckpt_dir=str(tmp_path), fault_plan=faults,
        ckpt_every=4, elastic=ElasticConfig(max_consecutive_nonfinite=3))
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert int(state.step) == 10

    failure = events_of(history, "elastic_failure")[0]
    assert failure["kind"] == "nonfinite_streak" and failure["step"] == 7
    assert events_of(history, "elastic_recovery")[0]["step"] == 4
    # no strategy change: the mesh is intact, only the data was sick
    assert events_of(history, "grad_sync_downgrade") == []

    clean = make_trainer(mesh, max_steps=10)
    clean_state, _ = clean.run(fresh_state(), log=lambda *a: None)
    assert_trees_equal(state.params, clean_state.params)
    assert_trees_equal(state.opt_state, clean_state.opt_state)


@pytest.mark.multidevice
def test_timeout_streak_triggers_rollback(mesh, tmp_path):
    faults = FaultPlan(timeout_steps=(3, 4, 5))
    trainer = make_trainer(
        mesh, max_steps=8, ckpt_dir=str(tmp_path), fault_plan=faults,
        ckpt_every=2, elastic=ElasticConfig(max_consecutive_timeouts=3))
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert int(state.step) == 8
    failure = events_of(history, "elastic_failure")[0]
    assert failure["kind"] == "timeout" and failure["step"] == 5
    assert events_of(history, "elastic_recovery")[0]["step"] == 2


@pytest.mark.multidevice
def test_recovery_budget_exhaustion_aborts(mesh, tmp_path):
    """A deterministic poison source (NOT once-only) reappears after every
    rollback; the supervisor must stop after max_recoveries instead of
    looping forever."""
    faults = FaultPlan(nan_grad_steps=(5, 6, 7))
    trainer = make_trainer(
        mesh, max_steps=10, ckpt_dir=str(tmp_path), fault_plan=faults,
        ckpt_every=4,
        elastic=ElasticConfig(max_consecutive_nonfinite=3,
                              max_recoveries=2))
    with pytest.raises(RuntimeError, match="recovery budget exhausted"):
        trainer.run(fresh_state(), log=lambda *a: None)


@pytest.mark.multidevice
def test_recovery_without_checkpoint_dir_aborts(mesh):
    faults = FaultPlan(axis_down_events=(("dy", 2),))
    trainer = make_trainer(mesh, max_steps=4, fault_plan=faults)
    with pytest.raises(RuntimeError, match="no valid checkpoint"):
        trainer.run(fresh_state(), log=lambda *a: None)


@pytest.mark.multidevice
def test_persistent_ckpt_dir_failure_run_still_completes(mesh, tmp_path):
    """The checkpoint filesystem dies for good after the first two saves:
    every later save fails (events, not aborts), the run completes, and
    latest_valid still resolves to the last pre-failure checkpoint."""
    faults = FaultPlan(ckpt_dir_fail_from=2)
    trainer = make_trainer(mesh, max_steps=8, ckpt_dir=str(tmp_path),
                           fault_plan=faults, ckpt_every=2)
    state, history = trainer.run(fresh_state(), log=lambda *a: None)
    assert int(state.step) == 8
    assert events_of(history, "checkpoint_failed")
    ok_steps = sorted(ev["step"] for ev in events_of(history, "checkpoint"))
    assert ok_steps == [0, 2]            # initial + first periodic only
    best = checkpoint.latest_valid(str(tmp_path), like=state)
    assert best is not None and best.endswith("step_00000002.npz")


# ---------------------------------------------------------------------------
# Async checkpoint writer
# ---------------------------------------------------------------------------

def small_state(step=0):
    s = TrainState.create(resnet.init(jax.random.key(1), CFG))
    return TrainState(s.params, s.opt_state, jnp.asarray(step, jnp.int32),
                      s.loss_scale, s.good_steps)


def test_async_writer_matches_sync_writer(tmp_path):
    """Files, manifests, and every read-side behavior (latest /
    latest_valid / restore) must be indistinguishable from the synchronous
    writer's output."""
    sync_dir, async_dir = str(tmp_path / "sync"), str(tmp_path / "async")
    states = [small_state(s) for s in (1, 2, 3)]
    for st in states:
        checkpoint.save(sync_dir, st, meta={"k": 1})
    w = AsyncCheckpointWriter()
    for st in states:
        w.save(async_dir, st, meta={"k": 1})
    assert w.flush(30)
    w.close()
    assert w.errors == []
    assert sorted(os.listdir(sync_dir)) == sorted(os.listdir(async_dir))
    for d in (sync_dir, async_dir):
        assert checkpoint.latest(d).endswith("step_00000003.npz")
        assert checkpoint.latest_valid(d, like=states[0]) \
            == checkpoint.latest(d)
    for name in os.listdir(sync_dir):
        if name.endswith(checkpoint.MANIFEST_SUFFIX):
            a = open(os.path.join(sync_dir, name), "rb").read()
            b = open(os.path.join(async_dir, name), "rb").read()
            assert a == b
    assert_trees_equal(
        checkpoint.restore(checkpoint.latest(sync_dir), states[0]).params,
        checkpoint.restore(checkpoint.latest(async_dir), states[0]).params)


def test_async_save_never_blocks_on_payload_io(tmp_path):
    """The worker is frozen inside the payload write while the caller's
    save() has already returned -- anything else would deadlock here."""
    gate, entered = threading.Event(), threading.Event()

    def hook(phase, attempt):
        if phase == "payload":
            entered.set()
            assert gate.wait(30)

    w = AsyncCheckpointWriter()
    path = w.save(str(tmp_path), small_state(1), io_hook=hook)
    # save() returned; the commit is demonstrably still in flight
    assert entered.wait(30)
    assert w.pending() == 1
    assert not os.path.exists(path)
    gate.set()
    assert w.flush(30)
    assert w.pending() == 0
    w.close()
    checkpoint.validate(path, like=small_state(1))


def test_async_bounded_queue_applies_backpressure(tmp_path):
    """With max_pending=1, a third save must block until the worker frees a
    slot -- bounded host memory, never a dropped checkpoint."""
    gate, entered = threading.Event(), threading.Event()

    def hook(phase, attempt):
        if phase == "payload":
            entered.set()
            assert gate.wait(30)

    w = AsyncCheckpointWriter(max_pending=1)
    w.save(str(tmp_path), small_state(1), io_hook=hook)   # worker holds it
    assert entered.wait(30)
    w.save(str(tmp_path), small_state(2))                 # fills the queue

    third_done = threading.Event()
    t = threading.Thread(
        target=lambda: (w.save(str(tmp_path), small_state(3)),
                        third_done.set()),
        daemon=True)
    t.start()
    assert not third_done.wait(0.3)       # blocked on the full queue
    assert w.pending() == 3
    gate.set()
    assert third_done.wait(30)
    assert w.flush(30)
    w.close()
    steps = [s for s, _ in checkpoint._candidates(str(tmp_path))]
    assert steps == [1, 2, 3]             # committed in enqueue order


def test_async_survives_midwrite_crash_and_retries(tmp_path):
    plan = FaultPlan(ckpt_crash_writes=(0,), ckpt_crashes_per_write=2)
    w = AsyncCheckpointWriter(retries=3, backoff_s=1e-4)
    path = w.save(str(tmp_path), small_state(1),
                  io_hook=plan.checkpoint_io_hook)
    assert w.flush(30)
    w.close()
    events = w.drain_events()
    kinds = [e["event"] for e in events]
    assert kinds.count("checkpoint_retry") == 2
    assert kinds[-1] == "checkpoint"
    assert w.errors == []
    checkpoint.validate(path, like=small_state(1))


def test_async_persistent_failure_surfaces_and_preserves_previous(tmp_path):
    prev = checkpoint.save(str(tmp_path), small_state(1))
    plan = FaultPlan(ckpt_dir_fail_from=0)
    w = AsyncCheckpointWriter(retries=2, backoff_s=1e-4)
    w.save(str(tmp_path), small_state(2), io_hook=plan.checkpoint_io_hook)
    assert w.flush(30)
    w.close()
    events = w.drain_events()
    assert events[-1]["event"] == "checkpoint_failed"
    assert len(w.errors) == 1
    assert isinstance(w.errors[0], checkpoint.CheckpointError)
    # the failed save left no torso and the previous checkpoint still wins
    assert checkpoint.latest_valid(str(tmp_path), like=small_state(1)) \
        == prev
    # a save after close() is a clean error, not a hang
    with pytest.raises(checkpoint.CheckpointError, match="closed"):
        w.save(str(tmp_path), small_state(3))


# ---------------------------------------------------------------------------
# Restore-after-partial-commit (satellite): torn payloads and manifest-less
# torsos must never load garbage
# ---------------------------------------------------------------------------

def test_restore_after_partial_commit_rejected_with_fallback(tmp_path):
    """A payload truncated *after* its manifest committed must raise
    CheckpointCorruptError (CRC/readability, not garbage params), and
    latest_valid must fall back to the previous checkpoint."""
    p1 = checkpoint.save(str(tmp_path), small_state(1))
    p2 = checkpoint.save(str(tmp_path), small_state(2))
    assert os.path.exists(checkpoint.manifest_path(p2))
    with open(p2, "r+b") as f:
        f.truncate(os.path.getsize(p2) * 2 // 3)
    with pytest.raises(checkpoint.CheckpointCorruptError,
                       match="unreadable payload|CRC|missing"):
        checkpoint.restore(p2, small_state(2))
    skipped = []
    best = checkpoint.latest_valid(str(tmp_path), like=small_state(1),
                                   on_skip=lambda p, r: skipped.append(p))
    assert best == p1 and skipped == [p2]
    restored = checkpoint.restore(best, small_state(1))
    assert int(restored.step) == 1


def test_partial_commit_payload_without_manifest_is_skipped(tmp_path):
    """The other torn window: payload renamed into place but the manifest
    write crashed (persistently). The npz torso exists under a committed
    name yet must be treated as uncommitted by latest_valid."""
    p1 = checkpoint.save(str(tmp_path), small_state(1))

    def manifest_crash(phase, attempt):
        if phase == "manifest":
            raise OSError("injected manifest-write crash")

    with pytest.raises(checkpoint.CheckpointError):
        checkpoint.save(str(tmp_path), small_state(2), retries=1,
                        backoff_s=1e-4, io_hook=manifest_crash)
    torso = os.path.join(str(tmp_path), "step_00000002.npz")
    assert os.path.exists(torso)
    assert not os.path.exists(checkpoint.manifest_path(torso))
    with pytest.raises(checkpoint.CheckpointCorruptError, match="manifest"):
        checkpoint.validate(torso)
    skipped = []
    best = checkpoint.latest_valid(str(tmp_path), like=small_state(1),
                                   on_skip=lambda p, r: skipped.append(p))
    assert best == p1 and skipped == [torso]
