"""Test session setup.

Collective/grad-sync tests need >1 device, so we ask the CPU platform for 8
host devices (cheap; NOT the 512-device production mesh -- that is only ever
forced inside launch/dryrun.py, which runs as its own process). All tests are
written to be device-count-agnostic given >= 8 devices.

The XLA_FLAGS guard must run before jax initializes its backends, i.e.
before any test module is imported -- conftest import time is early enough.
Unlike a plain ``setdefault``, the guard also repairs an inherited
XLA_FLAGS (e.g. from CI or a dev shell) that is missing the device-count
flag, so the ``multidevice`` tests behave identically everywhere.
"""

import os
import sys

_DEVCOUNT_FLAG = "--xla_force_host_platform_device_count"

_flags = os.environ.get("XLA_FLAGS", "")
if _DEVCOUNT_FLAG not in _flags:
    os.environ["XLA_FLAGS"] = (_flags + " " if _flags else "") + \
        f"{_DEVCOUNT_FLAG}=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# test modules import helpers (tests/_hyp.py) top-level; guarantee the tests
# dir is importable regardless of pytest's import-mode/rootdir resolution
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def pytest_collection_modifyitems(config, items):
    """Skip ``multidevice`` tests if the platform gave us fewer devices than
    the simulated 8 (e.g. XLA_FLAGS was locked by an earlier jax init)."""
    import jax
    import pytest

    if jax.device_count() >= 8:
        return
    skip = pytest.mark.skip(
        reason=f"needs >=8 devices, have {jax.device_count()} "
               f"(set XLA_FLAGS={_DEVCOUNT_FLAG}=8)")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
