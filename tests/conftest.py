"""Test session setup.

Collective/grad-sync tests need >1 device, so we ask the CPU platform for 8
host devices (cheap; NOT the 512-device production mesh -- that is only ever
forced inside launch/dryrun.py, which runs as its own process). All tests are
written to be device-count-agnostic given >= 8 devices.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")
