"""Launch-layer units: sharding rules, HLO stats parsing, shapes config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import SHAPES, long_context_variant
from repro.launch import hlo_stats
from repro.launch.mesh import cache_pspecs, param_pspecs


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


MESH = FakeMesh({"data": 16, "model": 16})


def test_param_rules_basic():
    params = {
        "embed": {"embedding": sds((256000, 4096))},
        "layer": {"mixer": {"q": {"kernel": sds((4096, 4096))},
                            "o": {"kernel": sds((4096, 4096))}},
                  "mlp": {"up": {"kernel": sds((4096, 16384))}},
                  "pre_norm": {"norm_scale": sds((4096,))}},
    }
    specs = param_pspecs(params, mesh=MESH)
    assert specs["embed"]["embedding"] == P("model", None)
    assert specs["layer"]["mixer"]["q"]["kernel"] == P(None, "model")
    assert specs["layer"]["mixer"]["o"]["kernel"] == P("model", None)
    assert specs["layer"]["pre_norm"]["norm_scale"] == P()


def test_param_rules_divisibility_fallback():
    # granite: 40 experts don't divide model=16 -> axis moves to d_ff dim
    params = {"mlp": {"experts": {"up": sds((40, 1536, 512))}}}
    specs = param_pspecs(params, mesh=MESH)
    # model axis moved off the non-divisible expert dim (40) onto d_model
    assert specs["mlp"]["experts"]["up"] == P(None, "model", None)
    # mamba vocab 50280 doesn't divide -> model moves to d_model dim
    params = {"embed": {"embedding": sds((50280, 2560))}}
    specs = param_pspecs(params, mesh=MESH)
    assert specs["embed"]["embedding"] == P(None, "model")


def test_param_rules_scanned_leading_dim():
    params = {"blocks": [{"mixer": {"q": {"kernel": sds((28, 4096, 4096))}}}]}
    specs = param_pspecs(params, mesh=MESH)
    assert specs["blocks"][0]["mixer"]["q"]["kernel"] == P(None, None, "model")


def test_param_rules_fsdp():
    params = {"layer": {"mlp": {"up": {"kernel": sds((4096, 16384))}}}}
    specs = param_pspecs(params, fsdp=True, mesh=MESH)
    assert specs["layer"]["mlp"]["up"]["kernel"] == P("data", "model")


def test_cache_rules():
    cache = {
        "prefix": [{"k": sds((128, 32768, 16, 128), jnp.bfloat16)}],
        "blocks": {"k": sds((28, 128, 32768, 8, 128), jnp.bfloat16)},
    }
    specs = cache_pspecs(cache, ("data",), MESH)
    # 16 kv heads divide -> heads sharded
    assert specs["prefix"][0]["k"] == P(("data",), None, "model", None)
    # 8 kv heads don't -> head_dim sharded; scanned leading dim unsharded
    assert specs["blocks"]["k"] == P(None, ("data",), None, None, "model")


def test_cache_rules_batch_one():
    cache = {"prefix": [{"k": sds((1, 32768, 16, 128), jnp.bfloat16)}]}
    specs = cache_pspecs(cache, ("data",), MESH)
    assert specs["prefix"][0]["k"] == P(None, None, "model", None)


def test_hlo_stats_parsing():
    text = """
      %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups=...
      %ar.1 = f32[1024]{0} all-reduce-start(%y), to_apply=%add
      %rs = bf16[256,16]{1,0} reduce-scatter(%z)
      %cp = f32[8,8]{1,0} collective-permute(%w)
      ROOT %t = (f32[8]{0}) tuple(%cp)
    """
    st = hlo_stats.collective_stats(text)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 4096 * 2
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 1024 * 4
    assert st["reduce-scatter"]["bytes"] == 256 * 16 * 2
    assert st["by_dtype"]["f32"] == 1024 * 4 + 64 * 4
    assert st["total_count"] == 4


def test_bucket_audit_reports_dropped_ops():
    """Regression (ISSUE 10): ops under the min_bytes floor used to vanish
    from the audit silently -- a sub-KiB fp32 bucket of a small model was
    simply missing. They must now be surfaced in the ``dropped`` entry."""
    text = """
      %ar0 = f32[65536]{0} all-reduce(%a), replica_groups=...
      %ar1 = f32[64]{0} all-reduce(%b), replica_groups=...
      %ar2 = f32[1]{0} all-reduce(%c), replica_groups=...
    """
    audit = hlo_stats.bucket_audit(text, min_bytes=1024)
    assert audit["num_exchanges"] == 1
    assert audit["dropped"]["count"] == 2
    assert audit["dropped"]["bytes"] == 64 * 4 + 4
    assert audit["dropped"]["min_bytes"] == 1024
    assert audit["dropped"]["by_kind"]["all-reduce"]["count"] == 2
    # floor 0 drops nothing
    audit0 = hlo_stats.bucket_audit(text, min_bytes=0)
    assert audit0["num_exchanges"] == 3
    assert audit0["dropped"]["count"] == 0


def test_dryrun_audit_floor_derived_from_schedule():
    """The dry-run's audit floor tracks the resolved schedule's smallest
    exchange instead of hardcoding 1 KiB (ISSUE 10 bugfix)."""
    from repro.launch.dryrun import _audit_floor
    # fp32 group of a small model: 272-byte exchange must stay in view
    assert _audit_floor({"min_exchange_bytes": 272}) == 272
    # huge buckets: clamp to the historical 1 KiB (still drops loss psums)
    assert _audit_floor({"min_exchange_bytes": 4 << 20}) == 1024
    # degenerate tiny exchange: never below 16 B (scalar metric psums)
    assert _audit_floor({"min_exchange_bytes": 4}) == 16
    # FSDP: no manual schedule -> historical floor
    assert _audit_floor({}) == 1024
    assert _audit_floor({"min_exchange_bytes": None}) == 1024


def test_shapes_and_long_variant():
    assert SHAPES["train_4k"].step == "train"
    assert SHAPES["long_500k"].step == "decode"
    from repro.configs import registry
    m = long_context_variant(registry.get("mamba2-2.7b"))
    assert m.pattern == ("ssd",)          # ssm untouched
    g = long_context_variant(registry.get("gemma-7b"))
    assert g.pattern == ("local",) and g.window == 32768


def test_dryrun_results_complete():
    """All 80 combos exist on disk and lowered successfully."""
    import glob
    import json
    files = glob.glob("experiments/dryrun/*.json")
    if len(files) < 80:
        pytest.skip("dry-run sweep artifacts not generated in this checkout")
    assert len(files) == 80
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        assert rec["cost"]["flops"] is not None
        assert rec["collectives"]["total_count"] >= 0
