"""Launch-layer units: sharding rules, HLO stats parsing, shapes config."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.shapes import SHAPES, long_context_variant
from repro.launch import hlo_stats
from repro.launch.mesh import cache_pspecs, param_pspecs


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


MESH = FakeMesh({"data": 16, "model": 16})


def test_param_rules_basic():
    params = {
        "embed": {"embedding": sds((256000, 4096))},
        "layer": {"mixer": {"q": {"kernel": sds((4096, 4096))},
                            "o": {"kernel": sds((4096, 4096))}},
                  "mlp": {"up": {"kernel": sds((4096, 16384))}},
                  "pre_norm": {"norm_scale": sds((4096,))}},
    }
    specs = param_pspecs(params, mesh=MESH)
    assert specs["embed"]["embedding"] == P("model", None)
    assert specs["layer"]["mixer"]["q"]["kernel"] == P(None, "model")
    assert specs["layer"]["mixer"]["o"]["kernel"] == P("model", None)
    assert specs["layer"]["pre_norm"]["norm_scale"] == P()


def test_param_rules_divisibility_fallback():
    # granite: 40 experts don't divide model=16 -> axis moves to d_ff dim
    params = {"mlp": {"experts": {"up": sds((40, 1536, 512))}}}
    specs = param_pspecs(params, mesh=MESH)
    # model axis moved off the non-divisible expert dim (40) onto d_model
    assert specs["mlp"]["experts"]["up"] == P(None, "model", None)
    # mamba vocab 50280 doesn't divide -> model moves to d_model dim
    params = {"embed": {"embedding": sds((50280, 2560))}}
    specs = param_pspecs(params, mesh=MESH)
    assert specs["embed"]["embedding"] == P(None, "model")


def test_param_rules_scanned_leading_dim():
    params = {"blocks": [{"mixer": {"q": {"kernel": sds((28, 4096, 4096))}}}]}
    specs = param_pspecs(params, mesh=MESH)
    assert specs["blocks"][0]["mixer"]["q"]["kernel"] == P(None, None, "model")


def test_param_rules_fsdp():
    params = {"layer": {"mlp": {"up": {"kernel": sds((4096, 16384))}}}}
    specs = param_pspecs(params, fsdp=True, mesh=MESH)
    assert specs["layer"]["mlp"]["up"]["kernel"] == P("data", "model")


def test_cache_rules():
    cache = {
        "prefix": [{"k": sds((128, 32768, 16, 128), jnp.bfloat16)}],
        "blocks": {"k": sds((28, 128, 32768, 8, 128), jnp.bfloat16)},
    }
    specs = cache_pspecs(cache, ("data",), MESH)
    # 16 kv heads divide -> heads sharded
    assert specs["prefix"][0]["k"] == P(("data",), None, "model", None)
    # 8 kv heads don't -> head_dim sharded; scanned leading dim unsharded
    assert specs["blocks"]["k"] == P(None, ("data",), None, None, "model")


def test_cache_rules_batch_one():
    cache = {"prefix": [{"k": sds((1, 32768, 16, 128), jnp.bfloat16)}]}
    specs = cache_pspecs(cache, ("data",), MESH)
    assert specs["prefix"][0]["k"] == P(None, None, "model", None)


def test_hlo_stats_parsing():
    text = """
      %ag = bf16[16,4096]{1,0} all-gather(%x), replica_groups=...
      %ar.1 = f32[1024]{0} all-reduce-start(%y), to_apply=%add
      %rs = bf16[256,16]{1,0} reduce-scatter(%z)
      %cp = f32[8,8]{1,0} collective-permute(%w)
      ROOT %t = (f32[8]{0}) tuple(%cp)
    """
    st = hlo_stats.collective_stats(text)
    assert st["all-gather"]["count"] == 1
    assert st["all-gather"]["bytes"] == 16 * 4096 * 2
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 1024 * 4
    assert st["reduce-scatter"]["bytes"] == 256 * 16 * 2
    assert st["by_dtype"]["f32"] == 1024 * 4 + 64 * 4
    assert st["total_count"] == 4


def test_shapes_and_long_variant():
    assert SHAPES["train_4k"].step == "train"
    assert SHAPES["long_500k"].step == "decode"
    from repro.configs import registry
    m = long_context_variant(registry.get("mamba2-2.7b"))
    assert m.pattern == ("ssd",)          # ssm untouched
    g = long_context_variant(registry.get("gemma-7b"))
    assert g.pattern == ("local",) and g.window == 32768


def test_dryrun_results_complete():
    """All 80 combos exist on disk and lowered successfully."""
    import glob
    import json
    files = glob.glob("experiments/dryrun/*.json")
    if len(files) < 80:
        pytest.skip("dry-run sweep artifacts not generated in this checkout")
    assert len(files) == 80
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        assert rec["cost"]["flops"] is not None
        assert rec["collectives"]["total_count"] >= 0
