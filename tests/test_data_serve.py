"""Data pipeline (synthetic + augmentations) and serving-path tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, strategies as st

from repro.data import augment
from repro.data.synthetic import SyntheticImageNet, SyntheticTokens
from repro.serve.decode import RequestBatcher


# ------------------------------------------------------------- synthetic --

def test_imagenet_batches_deterministic():
    data = SyntheticImageNet(num_classes=10, image_size=32)
    a1, l1 = data.batch(3, 4)
    a2, l2 = data.batch(3, 4)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    b1, _ = data.batch(4, 4)
    assert not np.allclose(np.asarray(a1), np.asarray(b1))


def test_imagenet_class_signal_exists():
    """Same-class samples are closer than cross-class (learnable)."""
    data = SyntheticImageNet(num_classes=4, image_size=32, noise=0.2)
    imgs, labels = data.batch(0, 64)
    imgs, labels = np.asarray(imgs), np.asarray(labels)
    centroids = np.stack([imgs[labels == c].mean(0) for c in range(4)
                          if (labels == c).any()])
    within = np.mean([np.linalg.norm(imgs[i] - centroids[labels[i]])
                      for i in range(len(imgs)) if labels[i] < len(centroids)])
    across = np.mean([np.linalg.norm(imgs[i] - centroids[(labels[i] + 1) %
                                                         len(centroids)])
                      for i in range(len(imgs)) if labels[i] < len(centroids)])
    assert within < across


def test_token_stream_learnable_structure():
    data = SyntheticTokens(vocab=1000)
    toks, labels = data.batch(0, 8, 64)
    assert toks.shape == (8, 64) and labels.shape == (8, 64)
    # the deterministic rule next = (prev*7+11) % V appears ~50% of the time
    det = (np.asarray(toks) * 7 + 11) % 1000
    match = (det[:, :-1] == np.asarray(toks)[:, 1:]).mean()
    assert 0.3 < match < 0.7, match


# ----------------------------------------------------------- augmentation --

def test_augment_shapes_and_finite():
    key = jax.random.key(0)
    imgs = jax.random.normal(jax.random.key(1), (4, 48, 48, 3))
    out = augment.augment(key, imgs, out_hw=(32, 32))
    assert out.shape == (4, 32, 32, 3)
    assert np.isfinite(np.asarray(out)).all()


def test_flip_is_exact_mirror():
    key = jax.random.key(0)
    imgs = jnp.arange(2 * 4 * 4 * 1, dtype=jnp.float32).reshape(2, 4, 4, 1)
    out = augment.random_flip(key, imgs)
    for b in range(2):
        ob, ib = np.asarray(out[b]), np.asarray(imgs[b])
        assert np.array_equal(ob, ib) or np.array_equal(ob, ib[:, ::-1])


def test_identity_affine_preserves_image():
    imgs = jax.random.normal(jax.random.key(2), (1, 16, 16, 3))
    out = augment.random_affine(jax.random.key(3), imgs, max_rot=0.0,
                                scale=(1.0, 1.0), max_shift=0.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(imgs),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 999))
def test_augment_property_bounded_output(seed):
    imgs = jnp.clip(jax.random.normal(jax.random.key(seed), (2, 16, 16, 3)), -3, 3)
    out = augment.augment(jax.random.key(seed + 1), imgs, out_hw=(16, 16))
    assert np.abs(np.asarray(out)).max() < 50


# ---------------------------------------------------------------- batcher --

def test_batcher_left_pad_and_truncate():
    b = RequestBatcher(batch_size=2, seq_len=4, pad_id=9)
    prompts, lens, n = b.pack([[1, 2], [1, 2, 3, 4, 5, 6]])
    assert n == 2
    np.testing.assert_array_equal(np.asarray(prompts[0]), [9, 9, 1, 2])
    np.testing.assert_array_equal(np.asarray(prompts[1]), [3, 4, 5, 6])


def test_batcher_rejects_overflow():
    b = RequestBatcher(batch_size=1, seq_len=4)
    with pytest.raises(ValueError):
        b.pack([[1], [2]])
