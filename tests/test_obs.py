"""Observability subsystem (repro.obs, docs/observability.md): metric
instrument semantics, crash-tolerant JSONL sinks (torn-tail + rotation),
nested span tracing with Chrome export, config fingerprints, and the
multidevice trainer smoke asserting the acceptance contract -- per-step
phase durations sum to the step wall time, per-bucket sync gauges match
the HLO bucket audit, the exported trace nests, and recording overhead
stays under 5% of a step."""

import json
import os
import threading
import time

import numpy as np
import pytest

from repro.obs import ObsConfig, Telemetry, fingerprint
from repro.obs.metrics import (DEFAULT_TIME_EDGES_S, MetricsRegistry,
                               NULL_REGISTRY)
from repro.obs.sink import JsonlSink, read_jsonl, read_run, run_paths
from repro.obs.tracing import Tracer


# ------------------------------------------------------------- metrics --

def test_counter_monotonic_and_rejects_negative():
    reg = MetricsRegistry()
    c = reg.counter("train/steps")
    c.inc()
    c.inc(2.5)
    assert c.value == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    # create-or-get: same instrument back
    assert reg.counter("train/steps") is c


def test_gauge_last_write_wins():
    reg = MetricsRegistry()
    g = reg.gauge("queue_depth")
    g.set(3)
    g.set(1)
    assert g.value == 1.0
    assert reg.snapshot()["queue_depth"] == {"type": "gauge", "value": 1.0}


def test_histogram_upper_bound_edge_semantics():
    reg = MetricsRegistry()
    h = reg.histogram("lat", edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    snap = h.snapshot()
    # le-semantics: 0.5 and the exact tie 1.0 both land in le=1.0;
    # 3.0 in le=4.0; 100.0 overflows to +inf
    assert [b["count"] for b in snap["buckets"]] == [2, 0, 1, 1]
    assert snap["buckets"][-1]["le"] == "inf"
    assert snap["count"] == 4
    assert snap["sum"] == pytest.approx(104.5)
    assert snap["min"] == 0.5 and snap["max"] == 100.0
    assert snap["mean"] == pytest.approx(104.5 / 4)


def test_histogram_edges_are_sorted_and_required():
    reg = MetricsRegistry()
    h = reg.histogram("x", edges=(4.0, 1.0, 2.0))
    assert h.edges == (1.0, 2.0, 4.0)
    with pytest.raises(ValueError):
        reg.histogram("empty", edges=())
    assert len(DEFAULT_TIME_EDGES_S) == 22


def test_registry_type_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("a")
    with pytest.raises(TypeError):
        reg.gauge("a")


def test_registry_names_prefix_filter():
    reg = MetricsRegistry()
    reg.counter("grad_sync/bucket00/nbytes")
    reg.counter("grad_sync/bucket01/nbytes")
    reg.counter("elastic/recoveries")
    assert reg.names("grad_sync/") == ["grad_sync/bucket00/nbytes",
                                       "grad_sync/bucket01/nbytes"]
    assert len(reg.names()) == 3


def test_metrics_thread_safety():
    reg = MetricsRegistry()
    c = reg.counter("n")
    h = reg.histogram("h", edges=(0.5,))

    def work():
        for _ in range(1000):
            c.inc()
            h.observe(1.0)

    threads = [threading.Thread(target=work) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 8000
    assert h.count == 8000
    assert h.snapshot()["buckets"][-1]["count"] == 8000  # all overflow


def test_null_registry_accepts_everything_records_nothing():
    NULL_REGISTRY.counter("x").inc(5)
    NULL_REGISTRY.gauge("y").set(3)
    NULL_REGISTRY.histogram("z").observe(1.0)
    assert NULL_REGISTRY.snapshot() == {}
    assert NULL_REGISTRY.names() == []


# ---------------------------------------------------------------- sink --

def test_sink_stamping_and_header(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path, run_id="abc123", meta={"source": "test"}) as s:
        s.emit({"kind": "metric", "v": 1})
        s.emit({"kind": "event", "event": "x"})
    rows = read_jsonl(path)
    assert rows[0]["kind"] == "run_header"
    assert rows[0]["meta"] == {"source": "test"}
    assert [r["seq"] for r in rows] == [0, 1, 2]
    assert all(r["run_id"] == "abc123" for r in rows)
    ts = [r["t_s"] for r in rows]
    assert ts == sorted(ts) and ts[0] >= 0.0


def test_sink_payload_cannot_override_stamps(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path, run_id="realrun") as s:
        s.emit({"kind": "summary", "run_id": "realrun", "seq": 999})
    row = read_jsonl(path)[1]
    assert row["run_id"] == "realrun"
    assert row["seq"] == 1          # sink stamp, not the payload's 999


def test_sink_emit_after_close_raises(tmp_path):
    s = JsonlSink(str(tmp_path / "m.jsonl"))
    s.close()
    s.close()                       # idempotent
    with pytest.raises(ValueError):
        s.emit({"kind": "metric"})


def test_sink_rotation_chain_ordering(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path, rotate_bytes=300, meta={}) as s:
        for i in range(40):
            s.emit({"kind": "metric", "i": i})
    chain = run_paths(path)
    assert len(chain) > 2 and chain[-1] == path
    assert chain[0] == path + ".1"  # oldest first
    rows = read_run(path)
    assert [r["seq"] for r in rows] == list(range(41))  # header + 40
    assert [r["i"] for r in rows[1:]] == list(range(40))


def test_torn_tail_dropped_mid_file_corruption_handled(tmp_path):
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as s:
        for i in range(5):
            s.emit({"kind": "metric", "i": i})
    # crash mid-write: a torn final line must be invisible to readers
    with open(path, "ab") as f:
        f.write(b'{"kind": "metr')
    rows = read_jsonl(path)
    assert len(rows) == 6 and rows[-1]["i"] == 4
    rows = read_jsonl(path, strict=True)    # a torn TAIL is fine even strict
    assert len(rows) == 6
    # mid-file garbage is real corruption: skipped lax, raised strict
    with open(path, "ab") as f:
        f.write(b'\n{"kind": "metric", "i": 99}\n')
    assert read_jsonl(path)[-1]["i"] == 99
    with pytest.raises(json.JSONDecodeError):
        read_jsonl(path, strict=True)


def test_sink_crash_consistency_any_truncation_point(tmp_path):
    """Chaos pattern: truncating the file at ANY byte offset must yield a
    clean prefix of the emitted records, never an exception -- the same
    either-old-or-new discipline as the checkpoint layer."""
    path = str(tmp_path / "m.jsonl")
    with JsonlSink(path) as s:
        for i in range(10):
            s.emit({"kind": "metric", "i": i, "pad": "x" * 7})
    blob = open(path, "rb").read()
    crash = str(tmp_path / "crash.jsonl")
    rng = np.random.RandomState(0)
    offsets = set(rng.randint(0, len(blob), size=50)) | {0, len(blob)}
    for cut in offsets:
        with open(crash, "wb") as f:
            f.write(blob[:cut])
        rows = read_jsonl(crash)
        assert [r["seq"] for r in rows] == list(range(len(rows)))


# ------------------------------------------------------------- tracing --

def test_span_nesting_depth_and_parent():
    tr = Tracer()
    with tr.span("step", step=3) as outer:
        with tr.span("sync/bucket3", step=3) as inner:
            time.sleep(0.002)
        assert inner.duration >= 0.002
    assert outer.depth == 0 and outer.parent is None
    assert inner.depth == 1 and inner.parent == "step"
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1 + 1e-6
    assert outer.duration >= inner.duration
    assert tr.spans("sync/bucket3", step=3) == [inner]
    bd = tr.phase_breakdown(3)
    assert set(bd) == {"step", "sync/bucket3"}


def test_span_exception_safety():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    sp = tr.spans("boom")[0]
    assert sp.error and sp.duration is not None
    with tr.span("after") as nxt:
        pass
    assert nxt.depth == 0              # stack unwound despite the raise


def test_disabled_tracer_yields_null_span():
    tr = Tracer(enabled=False)
    with tr.span("x") as sp:
        pass
    assert sp.duration == 0.0
    assert tr.spans() == []


def test_chrome_trace_export_loadable_and_nested(tmp_path):
    tr = Tracer()
    with tr.span("step", step=0):
        with tr.span("data", step=0):
            time.sleep(0.001)
        with tr.span("dispatch", step=0):
            time.sleep(0.001)
    path = str(tmp_path / "trace.json")
    n = tr.export_chrome_trace(path)
    doc = json.load(open(path))
    events = doc["traceEvents"]
    assert n == len(events) == 3
    assert all(e["ph"] == "X" for e in events)
    by_name = {e["name"]: e for e in events}
    step = by_name["step"]
    for child in ("data", "dispatch"):
        e = by_name[child]
        assert e["ts"] >= step["ts"]
        assert e["ts"] + e["dur"] <= step["ts"] + step["dur"] + 1.0  # µs
        assert e["args"]["step"] == 0


# -------------------------------------------------- fingerprint/telemetry --

def test_fingerprint_deterministic_and_key_order_free():
    a = fingerprint({"x": 1, "y": [1, 2], "z": "s"})
    b = fingerprint({"z": "s", "y": [1, 2], "x": 1})
    assert a == b and len(a) == 12
    assert fingerprint({"x": 2, "y": [1, 2], "z": "s"}) != a


def test_telemetry_events_summary_and_idempotent_close(tmp_path):
    path = str(tmp_path / "m.jsonl")
    tel = Telemetry(ObsConfig(metrics_path=path,
                              trace_path=str(tmp_path / "t.json")),
                    meta={"source": "test"})
    with tel.span("step", step=0):
        pass
    rec = tel.event("elastic_recovery", step=4)
    assert rec == {"kind": "event", "event": "elastic_recovery", "step": 4}
    tel.close()
    tel.close()
    rows = read_run(path)
    assert rows[-1]["kind"] == "summary"
    m = rows[-1]["metrics"]
    assert m["events/elastic_recovery"]["value"] == 1
    assert os.path.exists(str(tmp_path / "t.json"))


def test_telemetry_disabled_is_inert(tmp_path):
    tel = Telemetry(ObsConfig(enabled=False,
                              metrics_path=str(tmp_path / "no.jsonl")))
    assert tel.registry is NULL_REGISTRY
    assert tel.sink is None
    with tel.span("x") as sp:
        pass
    assert sp.duration == 0.0
    tel.event("whatever")
    tel.close()
    assert not os.path.exists(str(tmp_path / "no.jsonl"))


def test_record_bucket_metrics_gauges():
    import jax.numpy as jnp
    from repro.core.grad_sync import GradSyncConfig, record_bucket_metrics

    tree = {f"layer{i:02d}": {"kernel": np.zeros((64, 64), np.float32)}
            for i in range(4)}
    cfg = GradSyncConfig(fuse=True, comm_dtype=jnp.float32,
                         bucket_bytes=16 * 1024)
    reg = MetricsRegistry()
    layout = record_bucket_metrics(tree, cfg, reg)
    assert len(layout) == 4
    snap = reg.snapshot()
    assert snap["grad_sync/num_buckets"]["value"] == 4
    assert snap["grad_sync/num_exchanges"]["value"] == 4
    assert snap["grad_sync/total_nbytes"]["value"] == 4 * 64 * 64 * 4
    assert snap["grad_sync/bucket00/nbytes"]["value"] == 64 * 64 * 4
    # per-leaf sync (fuse=False): every large kernel is its own strategy
    # exchange; no small leaves here, so zero grouped buckets
    reg2 = MetricsRegistry()
    layout2 = record_bucket_metrics(
        tree, GradSyncConfig(fuse=False, comm_dtype=jnp.float32), reg2)
    assert [b["mode"] for b in layout2] == ["per_leaf"] * 4
    snap2 = reg2.snapshot()
    assert snap2["grad_sync/num_exchanges"]["value"] == 4
    assert snap2["grad_sync/per_leaf_exchanges"]["value"] == 4
    assert snap2["grad_sync/grouped_buckets"]["value"] == 0
    assert record_bucket_metrics(tree, cfg, None) == []


def test_record_bucket_metrics_clears_stale_gauges():
    """An elastic re-resolve that shrinks the schedule (or switches the
    sync path) must not leave the previous run's per-bucket gauges in the
    registry -- they would be exported as current (ISSUE 10 bugfix)."""
    import jax.numpy as jnp
    from repro.core.grad_sync import GradSyncConfig, record_bucket_metrics

    tree = {f"layer{i:02d}": {"kernel": np.zeros((64, 64), np.float32)}
            for i in range(4)}
    reg = MetricsRegistry()
    record_bucket_metrics(
        tree, GradSyncConfig(fuse=True, comm_dtype=jnp.float32,
                             bucket_bytes=16 * 1024), reg)
    assert "grad_sync/bucket03/nbytes" in reg.names("grad_sync/")
    # re-resolve to the fully-fused schedule: one bucket
    record_bucket_metrics(
        tree, GradSyncConfig(fuse=True, comm_dtype=jnp.float32,
                             bucket_bytes=0), reg)
    names = reg.names("grad_sync/")
    assert "grad_sync/bucket00/nbytes" in names
    assert "grad_sync/bucket03/nbytes" not in names
    assert reg.snapshot()["grad_sync/num_buckets"]["value"] == 1
    # switch to the per-leaf path: fused-only gauges must not linger
    record_bucket_metrics(
        tree, GradSyncConfig(fuse=False, comm_dtype=jnp.float32), reg)
    names = reg.names("grad_sync/")
    assert "grad_sync/num_buckets" not in names
    assert "grad_sync/bucket00/nbytes" not in names
    assert reg.snapshot()["grad_sync/per_leaf_exchanges"]["value"] == 4


def test_registry_remove_prefix():
    reg = MetricsRegistry()
    reg.counter("a/x").inc()
    reg.gauge("a/y").set(2)
    reg.gauge("ab").set(3)
    reg.gauge("b/z").set(4)
    assert reg.remove_prefix("a/") == 2
    assert reg.names() == ["ab", "b/z"]
    assert reg.remove_prefix("nope/") == 0
    with pytest.raises(ValueError):
        reg.remove_prefix("")


# ------------------------------------------- trainer smoke (acceptance) --

@pytest.mark.multidevice
def test_trainer_telemetry_end_to_end(tmp_path):
    """The acceptance contract on a real 8-device run: (a) per-step phase
    durations sum to within 10% of step wall time, (b) per-bucket sync
    gauges == the HLO bucket audit's exchange count, (c) the Chrome trace
    loads and nests data/dispatch/checkpoint under step, (d) recording
    overhead < 5% of a step, (e) history rows round-trip through JSONL on
    their ``kind`` marker."""
    import jax
    import jax.numpy as jnp
    from repro.core.grad_sync import GradSyncConfig
    from repro.core.schedules import BatchSchedule, BatchStage
    from repro.core.batch_control import build_plan
    from repro.launch import hlo_stats
    from repro.train.state import TrainState
    from repro.train.trainer import Trainer, TrainerConfig, make_train_step

    mesh = jax.make_mesh((2, 4), ("dy", "dx"))
    n_layers, width = 8, 64

    # comm-group-only params (no bn/bias/scale): with 16 KiB buckets every
    # 64x64 fp32 kernel is its own bucket -> exactly n_layers exchanges
    def init_params(key):
        keys = jax.random.split(key, n_layers)
        return {f"layer{i:02d}":
                {"kernel": jax.random.normal(keys[i], (width, width),
                                             jnp.float32) / width}
                for i in range(n_layers)}

    def loss_fn(params, batch, dp_axes):
        x, y = batch
        h = x
        for i in range(n_layers):
            h = jnp.tanh(h @ params[f"layer{i:02d}"]["kernel"])
        return (jnp.mean((h - y) ** 2), jnp.zeros((), jnp.float32))

    rng = np.random.RandomState(0)
    xs = rng.randn(512, width).astype(np.float32)
    ys = np.tanh(xs @ rng.randn(width, width).astype(np.float32) / width)

    def data_fn(i, gb):
        idx = (np.arange(gb) + i * gb) % len(xs)
        return xs[idx], ys[idx]

    metrics_path = str(tmp_path / "metrics.jsonl")
    trace_path = str(tmp_path / "trace.json")
    gcfg = GradSyncConfig(strategy="torus2d", fuse=True,
                          comm_dtype=jnp.float32, bucket_bytes=16 * 1024)
    tcfg = TrainerConfig(
        grad_sync=gcfg, log_every=2, ckpt_every_steps=2,
        obs=ObsConfig(metrics_path=metrics_path, trace_path=trace_path))
    plan = build_plan(BatchSchedule((BatchStage(0, 1.0, 2),)),
                      dataset_size=512, n_workers=8, max_steps=6)
    trainer = Trainer(mesh=mesh, dp_axes=("dy", "dx"), loss_fn=loss_fn,
                      cfg=tcfg, plan=plan, data_fn=data_fn,
                      checkpoint_dir=str(tmp_path / "ckpt"))
    state = TrainState.create(init_params(jax.random.key(0)))
    state, history = trainer.run(state, log=lambda *a: None)
    assert int(state.step) == 6

    rows = read_run(metrics_path)
    summary = [r for r in rows if r["kind"] == "summary"][-1]
    snap = summary["metrics"]

    # (a) phase coverage: the spans account for the step's wall time
    phase_rows = [r for r in rows if r.get("metric") == "step_phases"]
    assert len(phase_rows) == 6
    for r in phase_rows:
        covered = sum(r["phases"].values())
        assert covered >= 0.90 * r["wall_s"], (r["step"], r)
        assert covered <= 1.02 * r["wall_s"], (r["step"], r)

    # (b) per-bucket gauges == the compiled HLO's independent exchanges
    bucket_gauges = [n for n in snap
                    if n.startswith("grad_sync/bucket")
                    and n.endswith("/nbytes")]
    assert len(bucket_gauges) == n_layers
    assert snap["grad_sync/num_buckets"]["value"] == n_layers
    fn = make_train_step(loss_fn, mesh, ("dy", "dx"), tcfg, donate=False)
    batch = data_fn(0, 16)
    hlo = fn.lower(state, batch, jnp.asarray(0.0, jnp.float32),
                   jnp.asarray(16.0, jnp.float32)).compile().as_text()
    audit = hlo_stats.bucket_audit(hlo, min_bytes=1024)
    assert audit["num_exchanges"] == len(bucket_gauges)

    # (c) the Chrome trace loads and nests
    doc = json.load(open(trace_path))
    events = doc["traceEvents"]
    names = {e["name"] for e in events}
    assert {"step", "data", "dispatch", "sync_wait",
            "checkpoint"} <= names
    steps = sorted((e for e in events if e["name"] == "step"),
                   key=lambda e: e["ts"])
    assert len(steps) == 6
    s0 = steps[0]
    inner = [e for e in events if e["name"] in ("data", "dispatch")
             and s0["ts"] <= e["ts"] <= s0["ts"] + s0["dur"]]
    assert len(inner) >= 2
    for e in inner:
        assert e["ts"] + e["dur"] <= s0["ts"] + s0["dur"] + 1.0

    # (d) recording overhead: microbench the per-step telemetry bundle
    # (the spans + observes + emits _run_steps adds) against the mean
    # post-compile step wall time
    tel = Telemetry(ObsConfig(metrics_path=str(tmp_path / "bench.jsonl")))
    reg = tel.registry
    n_iters = 200
    t0 = time.perf_counter()
    for k in range(n_iters):
        with tel.span("step", step=k) as sp:
            with tel.span("data", step=k):
                pass
            with tel.span("dispatch", step=k):
                pass
            with tel.span("sync_wait", step=k):
                pass
            with tel.span("log", step=k):
                pass
            with tel.span("checkpoint", step=k):
                pass
        reg.histogram("step/wall_s").observe(sp.duration)
        reg.histogram("step/data_s").observe(0.0)
        reg.histogram("step/sync_wait_s").observe(0.0)
        reg.counter("train/steps").inc()
        reg.gauge("train/loss_scale").set(1.0)
        tel.emit({"kind": "metric", "metric": "step_phases", "step": k,
                  "wall_s": sp.duration, "phases": {"data": 0.0}})
    per_bundle = (time.perf_counter() - t0) / n_iters
    tel.close()
    steady = [r["wall_s"] for r in phase_rows[1:]]   # drop the compile step
    mean_step = sum(steady) / len(steady)
    assert per_bundle < 0.05 * mean_step, (per_bundle, mean_step)

    # (e) history kinds round-trip through JSONL
    assert all(h.get("kind") in ("metric", "event") for h in history)
    blob = "\n".join(json.dumps(h) for h in history)
    back = [json.loads(line) for line in blob.splitlines()]
    assert back == history
    assert {h["kind"] for h in back} == {"metric", "event"}
    events_h = [h for h in back if h["kind"] == "event"]
    assert any(e["event"] == "checkpoint" for e in events_h)
    # sink mirrored every history row (by kind count)
    mirrored = [r for r in rows
                if r["kind"] in ("metric", "event")
                and r.get("metric") != "step_phases"]
    assert len(mirrored) == len(history)
