"""``hypothesis`` if installed, else a minimal random-example fallback.

CI installs real hypothesis (see requirements-dev.txt) and gets full
shrinking/replay behaviour. Environments without it (the bare jax image)
still collect and run every property test: the fallback draws
``max_examples`` pseudo-random examples from a fixed seed, covering exactly
the API surface this suite uses:

    @settings(max_examples=N, deadline=None)
    @given(x=st.integers(a, b), ...)        # keyword style only

with strategies ``integers``, ``floats``, ``booleans``, ``lists``,
``sampled_from``. Anything fancier should go through real hypothesis.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect

    import numpy as np

    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class strategies:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.randint(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.randint(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: elements[rng.randint(len(elements))])

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = int(rng.randint(min_size, max_size + 1))
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
        del deadline

        def deco(f):
            f._hyp_max_examples = max_examples
            return f
        return deco

    def given(**kw_strategies):
        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                rng = np.random.RandomState(0xC0FFEE)
                n = getattr(wrapper, "_hyp_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    f(*args, **drawn, **kwargs)

            # hide the strategy-supplied params so pytest doesn't treat them
            # as fixtures (real hypothesis does this via its pytest plugin)
            sig = inspect.signature(f)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in kw_strategies])
            return wrapper
        return deco
