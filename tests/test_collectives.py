"""Correctness of the four all-reduce strategies x two lowerings.

Oracle: the sum of per-rank contributions (== lax.psum). Every strategy and
lowering must produce exactly the same mean/sum on every rank, for 1D and 2D
torus grids, odd shapes, and both dtypes.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.core import collectives
from repro.core.topology import TorusGrid, factorize, select_grid

pytestmark = pytest.mark.multidevice

STRATEGIES = ["psum", "ring", "hierarchical", "torus2d"]
LOWERINGS = ["xla", "ring"]


def make_mesh(shape, axes):
    return jax.make_mesh(shape, axes)


def run_allreduce(mesh, grid, strategy, lowering, per_rank):
    """per_rank: (world, chunk...) array; rank i contributes per_rank[i]."""
    spec = P(grid.axes)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False)
    def f(x):
        local = x[0]  # strip the sharded world dim -> this rank's tensor
        out = collectives.all_reduce(local, grid, strategy, lowering)
        return out[None]

    return np.asarray(jax.jit(f)(per_rank))


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_allreduce_2d_grid_matches_sum(strategy, lowering):
    mesh = make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))
    world = 8
    rng = np.random.RandomState(0)
    data = rng.randn(world, 16, 3).astype(np.float32)  # dim0=16 divisible by 8
    out = run_allreduce(mesh, grid, strategy, lowering, jnp.asarray(data))
    want = data.sum(axis=0)
    for r in range(world):
        np.testing.assert_allclose(out[r], want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("lowering", LOWERINGS)
def test_allreduce_1d_grid(strategy, lowering):
    mesh = make_mesh((8,), ("data",))
    grid = select_grid(("data",))
    rng = np.random.RandomState(1)
    data = rng.randn(8, 24).astype(np.float32)
    out = run_allreduce(mesh, grid, strategy, lowering, jnp.asarray(data))
    for r in range(8):
        np.testing.assert_allclose(out[r], data.sum(0), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_allreduce_three_axis_multipod(strategy):
    """(pod, data) as vertical+horizontal: the multi-pod mapping."""
    mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
    grid = select_grid(("pod", "data"))
    assert grid.h_axes == ("data",) and grid.v_axes == ("pod",)
    world = 4
    rng = np.random.RandomState(2)
    data = rng.randn(world, 8, 2).astype(np.float32)
    spec = P(("pod", "data"))

    @functools.partial(shard_map, mesh=mesh, in_specs=spec, out_specs=spec,
                       check_vma=False)
    def f(x):
        return collectives.all_reduce(x[0], grid, strategy, "xla")[None]

    out = np.asarray(jax.jit(f)(jnp.asarray(data)))
    for r in range(world):
        np.testing.assert_allclose(out[r], data.sum(0), rtol=1e-5, atol=1e-5)


def test_bf16_allreduce():
    mesh = make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))
    data = (np.arange(8 * 8).reshape(8, 8) % 5).astype(np.float32)
    x = jnp.asarray(data, dtype=jnp.bfloat16)
    out = run_allreduce(mesh, grid, "torus2d", "xla", x)
    np.testing.assert_allclose(np.asarray(out[0], np.float32),
                               data.sum(0), rtol=1e-2)


def test_ring_rs_ag_roundtrip_convention():
    """ring lowering RS followed by AG must reproduce XLA chunk order."""
    mesh = make_mesh((4,), ("d",))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"),
                       check_vma=False)
    def f(x):
        local = x[0]
        rs_ring = collectives._rs(local, "d", "ring")
        rs_xla = collectives._rs(local, "d", "xla")
        ag = collectives._ag(rs_ring, "d", "ring")
        return jnp.stack([jnp.sum(jnp.abs(rs_ring - rs_xla)),
                          jnp.sum(jnp.abs(ag - collectives._ag(rs_xla, "d", "xla")))])[None]

    data = np.random.RandomState(3).randn(4, 16).astype(np.float32)
    out = np.asarray(jax.jit(f)(jnp.asarray(data)))
    np.testing.assert_allclose(out, 0.0, atol=1e-5)


def test_factorize_table4_shapes():
    from repro.core.topology import paper_table4_grid
    assert factorize(16) == (4, 4)
    assert factorize(256) == (16, 16)
    assert factorize(12) == (3, 4)
    assert paper_table4_grid(3456) == (48, 72)
    assert paper_table4_grid(4096) == (64, 64)


def test_cost_model_paper_claims():
    """2D-torus: fewer steps than ring; less wire than hierarchical."""
    nbytes = 100e6  # ~ResNet-50 fp16 gradient
    ring = collectives.comm_cost_model("ring", nbytes, 32, 32, 50e9, 5e-6)
    hier = collectives.comm_cost_model("hierarchical", nbytes, 32, 32, 50e9, 5e-6)
    torus = collectives.comm_cost_model("torus2d", nbytes, 32, 32, 50e9, 5e-6)
    assert ring["steps"] == 2 * (1024 - 1)
    assert torus["steps"] == 2 * 31 + 2 * 31
    assert torus["steps"] == hier["steps"]          # same step count (paper)
    assert torus["wire_bytes"] < hier["wire_bytes"]  # X-times-smaller phase 2
    assert torus["seconds"] < ring["seconds"]


def test_torus_collective_schedule_in_hlo():
    """Structural check: the compiled torus2d shows RS/AR/AG phases and the
    explicit-ring lowering shows 2(X-1)+2(Y-1) collective-permutes."""
    import re
    mesh = make_mesh((2, 4), ("dy", "dx"))
    grid = TorusGrid(h_axes=("dx",), v_axes=("dy",))

    def lowered_text(lowering):
        @functools.partial(shard_map, mesh=mesh, in_specs=P(("dy", "dx")),
                           out_specs=P(("dy", "dx")), check_vma=False)
        def f(x):
            return collectives.all_reduce(x[0], grid, "torus2d", lowering)[None]
        x = jnp.zeros((8, 64), jnp.float32)
        return jax.jit(f).lower(x).compile().as_text()

    xla = lowered_text("xla")
    assert re.search(r"reduce-scatter", xla)
    assert re.search(r"all-reduce", xla)
    assert re.search(r"all-gather", xla)

    ring = lowered_text("ring")
    n_cp = len(re.findall(r"collective-permute(?:-start)?\(", ring))
    # X=4,Y=2: RS_h 3 + align 1, AR_v (RS 1 + align 1 + unalign 1 + AG 1),
    # AG_h (unalign 1 + 3) -- at least 2(X-1)+2(Y-1)=8 permutes, bounded above
    assert n_cp >= 8, ring
