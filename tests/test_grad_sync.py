"""Gradient-pytree synchronization: every strategy/lowering/mode must equal
the mean-of-per-rank-gradients oracle, over arbitrary pytrees (hypothesis)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _hyp import given, settings, strategies as st
from repro.compat import shard_map

from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core.topology import TorusGrid

pytestmark = pytest.mark.multidevice

MESH = None


def get_mesh():
    global MESH
    if MESH is None:
        MESH = jax.make_mesh((2, 4), ("dy", "dx"))
    return MESH


GRID = TorusGrid(h_axes=("dx",), v_axes=("dy",))
WORLD = 8


def run_sync(tree_per_rank, cfg):
    """tree_per_rank: pytree whose leaves have leading dim WORLD."""
    mesh = get_mesh()
    spec = P(("dy", "dx"))

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=spec, out_specs=spec, check_vma=False)
    def f(tree):
        local = jax.tree.map(lambda x: x[0], tree)
        out = sync_tree(local, GRID, cfg)
        return jax.tree.map(lambda x: x[None], out)

    return jax.jit(f)(tree_per_rank)


def oracle(tree_per_rank, mean=True):
    def red(x):
        s = np.asarray(x, np.float32).sum(0)
        return s / WORLD if mean else s
    return jax.tree.map(red, tree_per_rank)


def make_tree(rng):
    return {
        "dense": {"kernel": rng.randn(WORLD, 40, 7).astype(np.float32),
                  "bias": rng.randn(WORLD, 7).astype(np.float32)},
        "bn": {"scale": rng.randn(WORLD, 5).astype(np.float32)},
        "emb": rng.randn(WORLD, 33).astype(np.float32),
    }


def assert_replicated_close(out, want, rtol=1e-5, atol=1e-5):
    """Every rank of `out` holds the reduced value `want` (broadcast check)."""
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.broadcast_to(b, np.asarray(a).shape),
            rtol=rtol, atol=atol),
        out, want)


@pytest.mark.parametrize("strategy", ["psum", "ring", "hierarchical", "torus2d"])
@pytest.mark.parametrize("fuse", [True, False])
def test_sync_matches_mean_oracle(strategy, fuse):
    rng = np.random.RandomState(0)
    tree = make_tree(rng)
    cfg = GradSyncConfig(strategy=strategy, fuse=fuse, comm_dtype=jnp.float32)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    assert_replicated_close(out, oracle(tree))


@pytest.mark.parametrize("lowering", ["xla", "ring"])
def test_sync_ring_lowering(lowering):
    rng = np.random.RandomState(1)
    tree = make_tree(rng)
    cfg = GradSyncConfig(strategy="torus2d", lowering=lowering, fuse=True,
                         comm_dtype=jnp.float32)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    assert_replicated_close(out, oracle(tree))


def test_bf16_comm_close_to_fp32_oracle():
    rng = np.random.RandomState(2)
    tree = make_tree(rng)
    cfg = GradSyncConfig(strategy="torus2d", fuse=True, comm_dtype=jnp.bfloat16)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    want = oracle(tree)
    # bn/bias/scale go through the fp32 group -> exact; dense kernel is bf16
    assert_replicated_close(out["bn"]["scale"], want["bn"]["scale"])
    assert_replicated_close(out["dense"]["kernel"], want["dense"]["kernel"],
                            rtol=5e-2, atol=5e-2)


@settings(max_examples=20, deadline=None)
@given(
    shapes=st.lists(
        st.lists(st.integers(1, 9), min_size=0, max_size=3), min_size=1, max_size=5),
    strategy=st.sampled_from(["ring", "hierarchical", "torus2d"]),
    fuse=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_arbitrary_pytrees(shapes, strategy, fuse, seed):
    """Any collection of leaf shapes (incl. scalars, odd sizes) syncs to the
    exact mean on every rank."""
    rng = np.random.RandomState(seed)
    tree = {f"w{i}": rng.randn(WORLD, *s).astype(np.float32)
            for i, s in enumerate(shapes)}
    cfg = GradSyncConfig(strategy=strategy, fuse=fuse, comm_dtype=jnp.float32)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    assert_replicated_close(out, oracle(tree), rtol=1e-4)


def test_sum_mode():
    rng = np.random.RandomState(3)
    tree = {"w": rng.randn(WORLD, 16).astype(np.float32)}
    cfg = GradSyncConfig(strategy="torus2d", mean=False, comm_dtype=jnp.float32)
    out = run_sync(jax.tree.map(jnp.asarray, tree), cfg)
    assert_replicated_close(out["w"], tree["w"].sum(0))
