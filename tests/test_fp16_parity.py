"""fp16/bf16 comm-parity canary (ROADMAP "fp16 comm parity").

On this jaxlib (< 0.5) the SPMD partitioner hard-aborts -- an F-level
check, not a catchable exception -- when a partial-manual shard_map (model
axis auto) lowers scatter/gather collectives over a model-sharded operand:

    F ... spmd_partitioner.cc:512] Check failed:
        target.IsManualSubgroup() == sharding().IsManualSubgroup()

That abort is why (a) CPU dry-runs exchange gradients in f32 and the
roofline carries a /2 correction for bf16 traffic, and (b)
``compat.SUPPORTS_PARTIAL_MANUAL_COLLECTIVES`` gates the non-FSDP train
dry-run (see launch/dryrun.py). Because the process dies, the repro MUST
run in a subprocess; the test then asserts the program *compiles*, marked
``xfail(strict=True)``: while the env is broken it xfails quietly, and the
moment a jax upgrade fixes the lowering it XPASSes loudly -- the signal to
re-enable bf16 CPU exchanges, drop the /2 correction, and un-gate the
production-scale bucket audit (ROADMAP items)."""

import os
import subprocess
import sys

import pytest

_REPRO = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"
import sys
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import compat
from repro.core.grad_sync import GradSyncConfig, sync_tree
from repro.core.topology import TorusGrid

strategy = sys.argv[1]
mesh = jax.make_mesh((4, 2), ("data", "model"))
grid = TorusGrid(h_axes=("data",), v_axes=())
# the TPU-target config CPU cannot lower today: bf16 exchange of a
# model-sharded gradient under a partial-manual shard_map
cfg = GradSyncConfig(strategy=strategy, fuse=False,
                     comm_dtype=jnp.bfloat16, small_leaf_threshold=1)

def loss(w, x):
    return jnp.sum(jnp.tanh(x @ w))

def step(w, x):
    g = jax.grad(loss)(w, x)
    return sync_tree(g, grid, cfg)

smapped = compat.shard_map(step, mesh=mesh, in_specs=(P(), P("data")),
                           out_specs=P(), axis_names=frozenset({"data"}),
                           check_vma=False)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))
x = jax.ShapeDtypeStruct((8, 64), jnp.float32,
                         sharding=NamedSharding(mesh, P("data")))
jax.jit(smapped).lower(w, x).compile()
print("COMPILED_OK")
"""


def _run(strategy: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = (os.path.join(os.path.dirname(__file__), "..", "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", _REPRO, strategy],
                          capture_output=True, text=True, timeout=300,
                          env=env)


@pytest.mark.slow
def test_psum_control_compiles():
    """The all-reduce-only lowering of the same program compiles -- proves
    the harness is sound and the abort is specific to the scatter/gather
    (torus2d) path, not to bf16 or the sharding setup."""
    proc = _run("psum")
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "COMPILED_OK" in proc.stdout


@pytest.mark.slow
@pytest.mark.xfail(
    strict=True,
    reason="jaxlib < 0.5 SPMD partitioner aborts on partial-manual "
           "scatter/gather over model-sharded operands; an XPASS here "
           "means the env moved -- drop the f32-on-CPU override and the "
           "roofline /2 correction (ROADMAP: fp16 comm parity)")
def test_bf16_model_sharded_torus_exchange_compiles():
    proc = _run("torus2d")
    # while broken: SIGABRT (rc 134 / -6) from the F-check, never a python
    # exception -- assert on the *process* outcome
    if proc.returncode != 0:
        assert ("IsManualSubgroup" in proc.stderr
                or proc.returncode in (134, -6)), proc.stderr[-2000:]
    assert proc.returncode == 0, \
        f"SPMD partitioner abort (rc={proc.returncode})"
    assert "COMPILED_OK" in proc.stdout
